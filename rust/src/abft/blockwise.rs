//! Block-wise ABFT (paper §5.2): partition the K dimension into tiles,
//! checksum + verify each partial product independently, then accumulate.
//!
//! Rounding error grows with accumulation depth, so per-block verification
//! (depth `bk` instead of `K`) gets *tighter thresholds* — the paper's
//! Ascend integration uses (M, K, N) tiles of (128, 1024, 256) to "achieve
//! reliable detection while keeping overhead within the GEMM pipeline's
//! slack". Per-block verification also localizes the fault in K (which
//! block) in addition to the output column.
//!
//! [`BlockwiseFtGemm`] is the `block_k = KC` parameterization of the
//! shared (private) `pipeline` module — the same
//! detect/localize/correct/recompute implementation [`crate::abft::FtGemm`]
//! runs at `block_k = K`, executing on the same tiled parallel engine.
//!
//! **Deprecated**: blockwise is now a *policy*, not a type. Use
//! [`crate::abft::FtGemm`] with
//! `VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(k))`
//! — same pipeline, same bits. This wrapper remains for one release.

use crate::abft::pipeline;
use crate::abft::prepared::PreparedWeights;
use crate::abft::{VerifyPolicy, VerifyReport};
use crate::error::Result;
use crate::gemm::{GemmEngine, GemmOutput};
use crate::matrix::Matrix;
use crate::threshold::{Threshold, VabftThreshold};

/// Output of a block-wise protected multiply.
#[derive(Debug, Clone)]
pub struct BlockwiseOutput {
    /// The (possibly corrected) product, on the model's output grid.
    pub c: Matrix,
    /// What verification saw and did, across all K-blocks.
    pub report: VerifyReport,
    /// Which K-block each detection occurred in (parallel to
    /// `report.detections`).
    pub detection_blocks: Vec<usize>,
    /// Number of K-blocks the multiply was tiled into.
    pub blocks: usize,
}

/// Block-wise fault-tolerant GEMM over K tiles.
///
/// ```
/// # #![allow(deprecated)]
/// use vabft::prelude::*;
///
/// let mut rng = Xoshiro256pp::seed_from_u64(1);
/// let d = Distribution::normal_1_1();
/// let a = Matrix::sample(8, 96, &d, &mut rng);
/// let b = Matrix::sample(96, 16, &d, &mut rng);
///
/// let engine = GemmEngine::new(AccumModel::wide(Precision::Bf16));
/// let bw = BlockwiseFtGemm::new(engine, 32, VerifyPolicy::default());
/// let out = bw.multiply(&a, &b).unwrap();
/// assert_eq!(out.blocks, 3);                       // 96 = 3 × 32
/// assert_eq!(out.report.verdict, Verdict::Clean);
///
/// // Weight-stationary: prepare once, multiply many times — bitwise-equal.
/// let w = bw.prepare(&b);
/// let warm = bw.multiply_prepared(&a, &w).unwrap();
/// assert_eq!(warm.c.data(), out.c.data());
/// ```
#[deprecated(
    note = "use FtGemm with VerifyPolicy::with_granularity(VerifyGranularity::BlockK(k))"
)]
pub struct BlockwiseFtGemm {
    engine: GemmEngine,
    threshold: Box<dyn Threshold>,
    policy: VerifyPolicy,
    /// K tile depth (paper's NPU configuration uses 1024).
    pub block_k: usize,
}

#[allow(deprecated)]
impl BlockwiseFtGemm {
    /// Build a blockwise executor with the default V-ABFT threshold.
    pub fn new(engine: GemmEngine, block_k: usize, policy: VerifyPolicy) -> BlockwiseFtGemm {
        assert!(block_k > 0);
        BlockwiseFtGemm {
            engine,
            threshold: Box::new(VabftThreshold::default()),
            policy,
            block_k,
        }
    }

    /// Replace the default V-ABFT threshold algorithm.
    pub fn with_threshold(mut self, t: VabftThreshold) -> Self {
        self.threshold = Box::new(t);
        self
    }

    /// Replace the threshold algorithm with any [`Threshold`].
    pub fn with_threshold_box(mut self, t: Box<dyn Threshold>) -> Self {
        self.threshold = t;
        self
    }

    /// The engine this executor runs on.
    pub fn engine(&self) -> &GemmEngine {
        &self.engine
    }

    /// Precompute per-K-block checksum encodings and statistics for a
    /// weight matrix at this executor's `block_k` granularity. See
    /// [`PreparedWeights`].
    pub fn prepare(&self, b: &Matrix) -> PreparedWeights {
        PreparedWeights::prepare_blockwise(b, &self.engine, &self.policy, self.block_k)
    }

    /// Protected multiply with optional per-block fault injection
    /// (`inject(block_index, partial)` mutates the partial accumulator).
    pub fn multiply_with_injection(
        &self,
        a: &Matrix,
        b: &Matrix,
        mut inject: impl FnMut(usize, &mut Matrix),
    ) -> Result<BlockwiseOutput> {
        self.run_cold(a, b, Some(move |bi: usize, o: &mut GemmOutput| inject(bi, &mut o.acc)))
    }

    /// Protected multiply without injection. Under [`VerifyPolicy::fused`]
    /// each K-block's detection checks execute inside the packed GEMM
    /// epilogue.
    pub fn multiply(&self, a: &Matrix, b: &Matrix) -> Result<BlockwiseOutput> {
        self.run_cold(a, b, None::<fn(usize, &mut GemmOutput)>)
    }

    fn run_cold<F: FnMut(usize, &mut GemmOutput)>(
        &self,
        a: &Matrix,
        b: &Matrix,
        inject: Option<F>,
    ) -> Result<BlockwiseOutput> {
        let out = pipeline::run_blocks(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            b,
            self.block_k,
            inject,
        )?;
        Ok(BlockwiseOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }

    /// Protected multiply against prepared weights (the weight-stationary
    /// warm path): per-block encodings and statistics come from the
    /// handle, so no per-request O(K·N) work on B remains. Bitwise-equal
    /// to [`BlockwiseFtGemm::multiply`]. Errors if the handle's block
    /// granularity, model or verification point does not match.
    pub fn multiply_prepared(&self, a: &Matrix, w: &PreparedWeights) -> Result<BlockwiseOutput> {
        self.run_warm(a, w, None::<fn(usize, &mut GemmOutput)>)
    }

    /// Prepared-path multiply with per-block fault injection into the
    /// partial accumulator (the experiment hook).
    pub fn multiply_prepared_with_injection(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        mut inject: impl FnMut(usize, &mut Matrix),
    ) -> Result<BlockwiseOutput> {
        self.run_warm(a, w, Some(move |bi: usize, o: &mut GemmOutput| inject(bi, &mut o.acc)))
    }

    fn run_warm<F: FnMut(usize, &mut GemmOutput)>(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        inject: Option<F>,
    ) -> Result<BlockwiseOutput> {
        crate::ensure!(
            w.block_k() == self.block_k,
            "PreparedWeights block_k {} does not match executor block_k {}",
            w.block_k(),
            self.block_k
        );
        let out = pipeline::run_prepared(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            w,
            inject,
        )?;
        Ok(BlockwiseOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::abft::Verdict;
    use crate::fp::Precision;
    use crate::gemm::{AccumModel, ParallelismConfig};
    use crate::rng::{Distribution, Xoshiro256pp};
    use crate::threshold::ThresholdContext;

    fn operands(seed: u64, m: usize, k: usize, n: usize) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::normal_1_1();
        (Matrix::sample(m, k, &d, &mut rng), Matrix::sample(k, n, &d, &mut rng))
    }

    #[test]
    fn blockwise_matches_monolithic_product() {
        let (a, b) = operands(1, 8, 96, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let bw = BlockwiseFtGemm::new(GemmEngine::new(model), 32, VerifyPolicy::default());
        let out = bw.multiply(&a, &b).unwrap();
        assert_eq!(out.report.verdict, Verdict::Clean);
        assert_eq!(out.blocks, 3);
        // numerically close to the monolithic engine result (different
        // accumulation grouping → small fp differences)
        let mono = GemmEngine::new(model).matmul(&a, &b);
        assert!(out.c.max_abs_diff(&mono.c) < 0.1, "{}", out.c.max_abs_diff(&mono.c));
    }

    #[test]
    fn ragged_last_block() {
        let (a, b) = operands(2, 4, 50, 8); // 50 = 32 + 18
        let model = AccumModel::cpu(Precision::F64);
        let bw = BlockwiseFtGemm::new(GemmEngine::new(model), 32, VerifyPolicy::default());
        let out = bw.multiply(&a, &b).unwrap();
        assert_eq!(out.blocks, 2);
        assert_eq!(out.report.verdict, Verdict::Clean);
        let mono = GemmEngine::new(model).matmul(&a, &b);
        assert!(out.c.max_abs_diff(&mono.c) < 1e-10);
    }

    #[test]
    fn fault_is_attributed_to_its_block_and_corrected() {
        let (a, b) = operands(3, 8, 128, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let bw = BlockwiseFtGemm::new(GemmEngine::new(model), 64, VerifyPolicy::default());
        let clean = bw.multiply(&a, &b).unwrap();
        let out = bw
            .multiply_with_injection(&a, &b, |bi, acc| {
                if bi == 1 {
                    let v = acc.get(5, 3);
                    acc.set(5, 3, v + 8.0);
                }
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Corrected);
        assert_eq!(out.detection_blocks, vec![1], "fault must localize to block 1");
        assert_eq!(out.report.detections[0].row, 5);
        assert_eq!(out.report.detections[0].col, Some(3));
        assert!(out.c.max_abs_diff(&clean.c) < 1e-2);
    }

    #[test]
    fn per_block_thresholds_are_tighter_than_monolithic() {
        // The point of §5.2: depth-bk verification beats depth-K. Compare
        // the V-ABFT threshold of one block against the full-K threshold.
        let (a, b) = operands(4, 4, 1024, 64);
        let model = AccumModel::npu_fp32();
        let ctx = ThresholdContext::offline(model);
        let vab = VabftThreshold::default();
        let t_full = vab.thresholds(&a, &b, &ctx)[0];
        let a_blk = Matrix::from_fn(4, 128, |i, j| a.get(i, j));
        let b_blk = Matrix::from_fn(128, 64, |i, j| b.get(i, j));
        let t_blk = vab.thresholds(&a_blk, &b_blk, &ctx)[0];
        assert!(
            t_blk < t_full / 2.0,
            "block threshold {t_blk} should be ≪ full {t_full}"
        );
    }

    #[test]
    fn blockwise_results_independent_of_engine_parallelism() {
        // The unified pipeline runs on the tiled engine; per-block partials
        // (and hence thresholds, detections and outputs) must not depend on
        // the engine's thread count.
        let (a, b) = operands(5, 6, 96, 12);
        let model = AccumModel::wide(Precision::Bf16);
        let serial = BlockwiseFtGemm::new(GemmEngine::new(model), 32, VerifyPolicy::default());
        let parallel = BlockwiseFtGemm::new(
            GemmEngine::with_parallelism(model, ParallelismConfig::with_threads(4)),
            32,
            VerifyPolicy::default(),
        );
        let x = serial.multiply(&a, &b).unwrap();
        let y = parallel.multiply(&a, &b).unwrap();
        assert_eq!(x.c.data(), y.c.data(), "blockwise output must be thread-invariant");
        assert_eq!(x.report.verdict, y.report.verdict);
    }
}
