//! Checksum encoding (paper §2.2, Eq. 1–3).
//!
//! Row-checksum encoding appends two columns to B:
//! `B^r = [B | B·r1 | B·r2]` with `r1 = 1` (detection) and
//! `r2 = [1, 2, …, N]ᵀ` (localization). The product `C^f = A·B^r` then
//! carries `C^{r1} = A·B·r1` and `C^{r2} = A·B·r2` in its last two columns
//! — computed by the same GEMM hardware/schedule as C itself.
//!
//! Column encoding appends two *rows* to A instead:
//! `A^c = [A; c1·A; c2·A]` with `c1 = 1` and `c2 = [1, 2, …, M]` — the
//! gigacheck augmented-operand algebra. The product `C^f = A^c·B` then
//! carries column checksums of C in its last two rows, giving an
//! orthogonal syndrome direction that localizes the faulty *row* of a
//! column. [`EncodingMode`] selects row-only (the paper's evaluation,
//! single-event-upset model — the default), row+column (one-shot 2D
//! intersection) or the grid decode (iterative row/column peeling,
//! multi-fault bursts). All modes are orthogonal to
//! [`crate::gemm::ReduceStrategy`], and all ride the packed operands
//! without changing any data element's rounding schedule.

use crate::fp::Precision;
use crate::gemm::GemmEngine;
use crate::matrix::Matrix;

/// Which checksum directions ride the packed operands — orthogonal to
/// [`crate::gemm::ReduceStrategy`] (the schedule *within* a reduction)
/// and to the verify point (where the syndromes are read).
///
/// The 2D modes share the same encodings (B-side checksum columns +
/// A-side checksum rows); they differ only in the *decode*: `RowCol`
/// intersects row and column syndromes once, `Grid` peels iteratively
/// (correct what is localizable, update the remaining syndromes
/// incrementally, repeat), which recovers burst patterns one-shot 2D
/// decoding cannot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EncodingMode {
    /// B-side row checksums only (Eq. 1–3) — the paper's configuration
    /// and the default. One fault per K-block localizes; multi-fault
    /// rows fall back to recompute.
    RowOnly,
    /// Row + column checksums, one-shot syndrome intersection: a
    /// row-inconsistent multi-fault pattern is repaired via the column
    /// direction when every struck column localizes its faulty row.
    RowCol,
    /// Grid-like decode over the same 2D encodings: iterative row/column
    /// peeling with incremental syndrome updates (PAPERS.md "grid-like
    /// error-correcting codes"), correcting multi-fault bursts that
    /// defeat one-shot 2D intersection.
    Grid,
}

impl EncodingMode {
    /// Every mode, in report order.
    pub const ALL: [EncodingMode; 3] =
        [EncodingMode::RowOnly, EncodingMode::RowCol, EncodingMode::Grid];

    /// Short lowercase name used in CLIs and reports.
    pub fn name(self) -> &'static str {
        match self {
            EncodingMode::RowOnly => "row",
            EncodingMode::RowCol => "rowcol",
            EncodingMode::Grid => "grid",
        }
    }

    /// Parse a CLI name (`row | rowcol | grid`).
    pub fn parse(s: &str) -> Option<EncodingMode> {
        match s {
            "row" | "rowonly" | "row-only" => Some(EncodingMode::RowOnly),
            "rowcol" | "row-col" | "2d" => Some(EncodingMode::RowCol),
            "grid" => Some(EncodingMode::Grid),
            _ => None,
        }
    }

    /// Whether the mode carries A-side column checksums (and hence the
    /// column-direction thresholds and the 2D repair stages).
    pub fn two_dimensional(self) -> bool {
        !matches!(self, EncodingMode::RowOnly)
    }
}

/// The linear position weight w(j) = j + 1 used by r2 (Eq. 9's
/// `j = D2/D1 − 1` inversion assumes exactly this).
#[inline]
pub fn position_weight(j: usize) -> f64 {
    (j + 1) as f64
}

/// Both checksum reductions of every row of (input-quantized) `bq` in one
/// shot: returns (B·r1, B·r2), *unquantized* (callers round onto their
/// storage grid).
///
/// The products ride the **packed parallel engine**
/// ([`GemmEngine::matmul_work`]) as a K×N · N×2 GEMM against the columns
/// `[1 | w]`: for every built-in accumulation model the engine schedule
/// of that GEMM is element-for-element the schedule of
/// [`GemmEngine::reduce`] / [`GemmEngine::dot`] (multiplying by the exact
/// 1.0 is a no-op rounding, and product/step roundings line up one to
/// one), so the results are bitwise-identical to the per-row loop —
/// verified by `routed_checksums_match_per_row_reference` below. The
/// per-row loop is kept for exotic models whose *work* grid cannot
/// represent the input values (where `q_work(x·1) = x` would not hold).
fn checksum_products(bq: &[f64], k: usize, n: usize, engine: &GemmEngine) -> (Vec<f64>, Vec<f64>) {
    if gemm_routable(engine) {
        let mut rhs = vec![0.0f64; n * 2];
        for j in 0..n {
            rhs[2 * j] = 1.0;
            rhs[2 * j + 1] = position_weight(j);
        }
        let cs = engine.matmul_work(bq, &rhs, k, n, 2);
        ((0..k).map(|r| cs[2 * r]).collect(), (0..k).map(|r| cs[2 * r + 1]).collect())
    } else {
        let weights: Vec<f64> = (0..n).map(position_weight).collect();
        let mut r1 = Vec::with_capacity(k);
        let mut r2 = Vec::with_capacity(k);
        for row in 0..k {
            let rq = &bq[row * n..(row + 1) * n];
            r1.push(engine.reduce(rq));
            r2.push(engine.dot(rq, &weights));
        }
        (r1, r2)
    }
}

/// One checksum reduction of every row of `bq` (r1 when `weighted` is
/// false, r2 otherwise) — the K×N · N×1 form of [`checksum_products`]
/// for callers that need a single column and shouldn't pay for both.
fn checksum_column(
    bq: &[f64],
    k: usize,
    n: usize,
    engine: &GemmEngine,
    weighted: bool,
) -> Vec<f64> {
    if gemm_routable(engine) {
        let rhs: Vec<f64> =
            (0..n).map(|j| if weighted { position_weight(j) } else { 1.0 }).collect();
        engine.matmul_work(bq, &rhs, k, n, 1)
    } else {
        let weights: Vec<f64> = (0..n).map(position_weight).collect();
        (0..k)
            .map(|row| {
                let rq = &bq[row * n..(row + 1) * n];
                if weighted {
                    engine.dot(rq, &weights)
                } else {
                    engine.reduce(rq)
                }
            })
            .collect()
    }
}

/// Whether this engine's checksum reductions can ride the packed GEMM:
/// true whenever multiplying an input-grid value by exactly 1.0 and
/// rounding to the work grid is an identity (native f32/f64 work
/// precisions, or generic work == input). See [`checksum_products`].
fn gemm_routable(engine: &GemmEngine) -> bool {
    let model = engine.model();
    matches!(model.work, Precision::F32 | Precision::F64) || model.input == model.work
}

/// `b` quantized onto the engine's input grid — the values the GEMM
/// actually consumes, which is what the checksums must cover.
fn input_quantized(b: &Matrix, engine: &GemmEngine) -> Vec<f64> {
    let mut bq = b.data().to_vec();
    engine.model().input.quantize_slice(&mut bq);
    bq
}

/// B·r1 per row of B: the plain row sums of the *input-quantized* row
/// (the GEMM consumes B on the input grid, so the checksum must cover
/// exactly those values), reduced with the engine's schedule and stored on
/// the engine's *input* grid (hardware stores the encoded columns in the
/// operand precision).
pub fn r1_checksum_of_b(b: &Matrix, engine: &GemmEngine) -> Vec<f64> {
    let grid = offline_checksum_grid(engine);
    let bq = input_quantized(b, engine);
    let mut r1 = checksum_column(&bq, b.rows(), b.cols(), engine, false);
    grid.quantize_slice(&mut r1);
    r1
}

/// B·r2 per row of B: position-weighted row sums (input-quantized data,
/// input-grid storage).
pub fn r2_checksum_of_b(b: &Matrix, engine: &GemmEngine) -> Vec<f64> {
    let grid = offline_checksum_grid(engine);
    let bq = input_quantized(b, engine);
    let mut r2 = checksum_column(&bq, b.rows(), b.cols(), engine, true);
    grid.quantize_slice(&mut r2);
    r2
}

/// Storage grid of offline checksum columns: the *finer* of the input and
/// output precisions. For BF16→BF16 GEMM this is BF16 (the encoded columns
/// are ordinary operands); for FP8→FP16 GEMM the checksums live in FP16 —
/// §3.6's rule that FP8 verification is governed by the output precision
/// requires encodings at least that fine (an FP8 checksum of a ~K-element
/// sum would drown the signal in input-grid quantization).
pub fn offline_checksum_grid(engine: &GemmEngine) -> crate::fp::Precision {
    let m = engine.model();
    if m.out.mantissa_bits() > m.input.mantissa_bits() {
        m.out
    } else {
        m.input
    }
}

/// Row/column checksum encodings of an operand pair.
#[derive(Debug, Clone)]
pub struct ChecksumEncoding {
    /// `B^r = [B | B·r1 | B·r2]`, shape K × (N+2).
    pub b_encoded: Matrix,
    /// Original N (number of data columns in `b_encoded`).
    pub n: usize,
    /// Checksum columns stored in the *work* precision instead of the
    /// input precision — the fused-kernel (online) configuration, where
    /// the encodings never leave the FP32 datapath (§3.6). Offline
    /// encodings live on the input grid like any other GEMM operand.
    pub wide: bool,
}

impl ChecksumEncoding {
    /// Encode B with row checksums under the engine's schedule, checksum
    /// columns stored on the *input* grid (offline ABFT: the encoded
    /// columns are ordinary GEMM inputs, e.g. BF16 on an NPU).
    pub fn encode_b(b: &Matrix, engine: &GemmEngine) -> ChecksumEncoding {
        Self::encode_b_impl(b, engine, false)
    }

    /// Encode B with checksum columns kept in the work precision (FP32)
    /// — the fused-kernel/online configuration that enables e_max ≈ 1e-6
    /// thresholds for low-precision GEMM (§3.6). Pair with
    /// [`crate::gemm::GemmEngine::matmul_mixed`] so the engine does not
    /// requantize the wide columns.
    pub fn encode_b_wide(b: &Matrix, engine: &GemmEngine) -> ChecksumEncoding {
        Self::encode_b_impl(b, engine, true)
    }

    fn encode_b_impl(b: &Matrix, engine: &GemmEngine, wide: bool) -> ChecksumEncoding {
        let (k, n) = (b.rows(), b.cols());
        let grid = if wide { engine.model().work } else { offline_checksum_grid(engine) };
        // Checksums must cover the values the GEMM actually consumes: the
        // input-quantized B. Both reductions of all K rows run as one
        // K×N·N×2 product on the packed engine (see checksum_products).
        let bq = input_quantized(b, engine);
        let (r1, r2) = checksum_products(&bq, k, n, engine);
        let mut be = Matrix::zeros(k, n + 2);
        for row in 0..k {
            be.row_mut(row)[..n].copy_from_slice(b.row(row));
            be.set(row, n, grid.quantize(r1[row]));
            be.set(row, n + 1, grid.quantize(r2[row]));
        }
        ChecksumEncoding { b_encoded: be, n, wide }
    }

    /// Number of trailing columns the engine must not requantize to the
    /// input grid (always the two checksum columns: they are stored on
    /// their own grid — work precision when `wide`, the finer of
    /// input/output otherwise — and `matmul_mixed`'s work-precision
    /// quantization is a no-op for values already on a coarser grid).
    pub fn wide_cols(&self) -> usize {
        2
    }

    /// Split an encoded product `C^f = A·B^r` into (C, C^{r1}, C^{r2}).
    pub fn split_product(&self, cf: &Matrix) -> (Matrix, Vec<f64>, Vec<f64>) {
        assert_eq!(cf.cols(), self.n + 2);
        let m = cf.rows();
        let mut c = Matrix::zeros(m, self.n);
        let mut cr1 = Vec::with_capacity(m);
        let mut cr2 = Vec::with_capacity(m);
        for i in 0..m {
            let row = cf.row(i);
            c.row_mut(i).copy_from_slice(&row[..self.n]);
            cr1.push(row[self.n]);
            cr2.push(row[self.n + 1]);
        }
        (c, cr1, cr2)
    }
}

/// Both column-checksum reductions of every column of (input-quantized)
/// `aq` (M×K, row-major) in one shot: returns (c1·A, c2·A), each length
/// K, *unquantized*.
///
/// The reductions ride the packed engine as one 2×M · M×K GEMM with the
/// weight rows `[1 … 1; w(0) … w(M−1)]` on the left — the transpose of
/// [`checksum_products`]'s routing, with the identical bitwise argument:
/// multiplying by the exact 1.0 (or the exactly-representable small
/// integer weight) and reducing with the engine schedule matches the
/// per-column [`GemmEngine::reduce`]/[`GemmEngine::dot`] loop
/// element for element (`routed_column_checksums_match_reference`
/// pins this). The per-column fallback covers the exotic models
/// [`gemm_routable`] excludes.
fn column_checksum_products(
    aq: &[f64],
    m: usize,
    k: usize,
    engine: &GemmEngine,
) -> (Vec<f64>, Vec<f64>) {
    if gemm_routable(engine) {
        let mut lhs = vec![0.0f64; 2 * m];
        for i in 0..m {
            lhs[i] = 1.0;
            lhs[m + i] = position_weight(i);
        }
        let cs = engine.matmul_work(&lhs, aq, 2, m, k);
        (cs[..k].to_vec(), cs[k..].to_vec())
    } else {
        let mut col = vec![0.0; m];
        let weights: Vec<f64> = (0..m).map(position_weight).collect();
        let mut c1 = Vec::with_capacity(k);
        let mut c2 = Vec::with_capacity(k);
        for j in 0..k {
            for i in 0..m {
                col[i] = aq[i * k + j];
            }
            c1.push(engine.reduce(&col));
            c2.push(engine.dot(&col, &weights));
        }
        (c1, c2)
    }
}

/// A-side column-checksum encoding: `A^c = [A; c1·A; c2·A]`, shape
/// (M+2) × K — the gigacheck augmented-operand form. The product
/// `C^f = A^c·B` carries column checksums of C in its last two rows,
/// computed by the same GEMM hardware/schedule as C itself; with a
/// row-encoded B the corner 2×2 block is the (unused) checksum-of-
/// checksums. The data rows of `a_encoded` are the original A bits —
/// the checksum rows ride along without perturbing any data row's
/// quantization or reduction schedule (pair with
/// [`crate::gemm::GemmEngine::matmul_mixed_2d`]).
#[derive(Debug, Clone)]
pub struct ColumnEncoding {
    /// `A^c = [A; c1·A; c2·A]`, shape (M+2) × K.
    pub a_encoded: Matrix,
    /// Original M (number of data rows in `a_encoded`).
    pub m: usize,
    /// Checksum rows stored in the *work* precision (online/fused
    /// configuration) instead of the input precision — the same rule as
    /// [`ChecksumEncoding::wide`].
    pub wide: bool,
}

impl ColumnEncoding {
    /// Encode A with column checksums on the offline storage grid (the
    /// finer of input/output — the encoded rows are ordinary operands).
    pub fn encode_a(a: &Matrix, engine: &GemmEngine) -> ColumnEncoding {
        Self::encode_a_impl(a, engine, false)
    }

    /// Encode A with checksum rows kept in the work precision — the
    /// online configuration, mirroring [`ChecksumEncoding::encode_b_wide`].
    pub fn encode_a_wide(a: &Matrix, engine: &GemmEngine) -> ColumnEncoding {
        Self::encode_a_impl(a, engine, true)
    }

    fn encode_a_impl(a: &Matrix, engine: &GemmEngine, wide: bool) -> ColumnEncoding {
        let (m, k) = (a.rows(), a.cols());
        let grid = if wide { engine.model().work } else { offline_checksum_grid(engine) };
        // Checksums cover the values the GEMM actually consumes: the
        // input-quantized A (mirrors encode_b_impl).
        let mut aq = a.data().to_vec();
        engine.model().input.quantize_slice(&mut aq);
        let (c1, c2) = column_checksum_products(&aq, m, k, engine);
        let mut ae = Matrix::zeros(m + 2, k);
        for i in 0..m {
            ae.row_mut(i).copy_from_slice(a.row(i));
        }
        for j in 0..k {
            ae.set(m, j, grid.quantize(c1[j]));
            ae.set(m + 1, j, grid.quantize(c2[j]));
        }
        ColumnEncoding { a_encoded: ae, m, wide }
    }

    /// Number of trailing rows the engine must not requantize to the
    /// input grid (always the two checksum rows — same storage-grid
    /// argument as [`ChecksumEncoding::wide_cols`]).
    pub fn wide_rows(&self) -> usize {
        2
    }

    /// Split an encoded product `C^f = A^c·B` into (C, C^{c1}, C^{c2}):
    /// the data rows and the two column-checksum rows. `cf` may carry
    /// row-checksum columns too (the grid product is (M+2) × (N+2)) —
    /// the full rows are returned and the caller splits columns via
    /// [`ChecksumEncoding::split_product`].
    pub fn split_product(&self, cf: &Matrix) -> (Matrix, Vec<f64>, Vec<f64>) {
        assert_eq!(cf.rows(), self.m + 2);
        let n = cf.cols();
        let mut c = Matrix::zeros(self.m, n);
        for i in 0..self.m {
            c.row_mut(i).copy_from_slice(cf.row(i));
        }
        (c, cf.row(self.m).to_vec(), cf.row(self.m + 1).to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{AccumModel, ReduceStrategy};
    use crate::rng::{Distribution, Xoshiro256pp};

    fn engine_f64() -> GemmEngine {
        GemmEngine::new(AccumModel::cpu(Precision::F64))
    }

    #[test]
    fn routed_checksums_match_per_row_reference() {
        // The packed-engine routing (one K×N·N×2 GEMM) must be
        // bitwise-identical to the pre-packing implementation: per-row
        // engine.reduce / engine.dot on the input-quantized rows. Covers
        // all three kernel dispatch paths (f64, f32, generic) and all
        // three strategies.
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let d = Distribution::normal_1_1();
        let b = Matrix::sample(33, 19, &d, &mut rng);
        let models = [
            AccumModel::cpu(Precision::F64),          // f64 pairwise
            AccumModel::gpu_highprec(Precision::F64), // f64 sequential
            AccumModel::cpu(Precision::F32),          // f32 pairwise
            AccumModel::gpu_highprec(Precision::F32), // f32 sequential
            AccumModel::wide(Precision::Bf16),        // f32 work, bf16 input
            AccumModel::fp8(Precision::F8E4M3),       // f32 work, fp8 input
            AccumModel::cpu(Precision::Bf16),         // generic pairwise
            AccumModel {
                input: Precision::F16,
                work: Precision::F16,
                strategy: ReduceStrategy::Fma,
                out: Precision::F16,
            }, // generic fma
        ];
        for model in models {
            let engine = GemmEngine::new(model);
            let weights: Vec<f64> = (0..b.cols()).map(position_weight).collect();
            let grid = offline_checksum_grid(&engine);
            let mut row_q = vec![0.0; b.cols()];
            let mut want_r1 = Vec::new();
            let mut want_r2 = Vec::new();
            for r in 0..b.rows() {
                for (dst, &s) in row_q.iter_mut().zip(b.row(r)) {
                    *dst = model.input.quantize(s);
                }
                want_r1.push(grid.quantize(engine.reduce(&row_q)));
                want_r2.push(grid.quantize(engine.dot(&row_q, &weights)));
            }
            // Single-column routing (the standalone checksum helpers)…
            let got_r1 = r1_checksum_of_b(&b, &engine);
            let got_r2 = r2_checksum_of_b(&b, &engine);
            // …and the paired K×N·N×2 routing used by encode_b.
            let enc = ChecksumEncoding::encode_b(&b, &engine);
            for r in 0..b.rows() {
                assert_eq!(
                    got_r1[r].to_bits(),
                    want_r1[r].to_bits(),
                    "r1 row {r} diverged under {model:?}"
                );
                assert_eq!(
                    got_r2[r].to_bits(),
                    want_r2[r].to_bits(),
                    "r2 row {r} diverged under {model:?}"
                );
                assert_eq!(
                    enc.b_encoded.get(r, b.cols()).to_bits(),
                    want_r1[r].to_bits(),
                    "encoded r1 row {r} diverged under {model:?}"
                );
                assert_eq!(
                    enc.b_encoded.get(r, b.cols() + 1).to_bits(),
                    want_r2[r].to_bits(),
                    "encoded r2 row {r} diverged under {model:?}"
                );
            }
        }
    }

    #[test]
    fn r1_is_row_sums() {
        let b = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r1 = r1_checksum_of_b(&b, &engine_f64());
        assert_eq!(r1, vec![6.0, 15.0]);
    }

    #[test]
    fn r2_is_position_weighted() {
        let b = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r2 = r2_checksum_of_b(&b, &engine_f64());
        // 1·1 + 2·2 + 3·3 = 14; 1·4 + 2·5 + 3·6 = 32
        assert_eq!(r2, vec![14.0, 32.0]);
    }

    #[test]
    fn encode_split_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let d = Distribution::uniform_pm1();
        let b = Matrix::sample(8, 5, &d, &mut rng);
        let a = Matrix::sample(4, 8, &d, &mut rng);
        let engine = engine_f64();
        let enc = ChecksumEncoding::encode_b(&b, &engine);
        assert_eq!(enc.b_encoded.cols(), 7);
        let cf = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols()).c;
        let (c, cr1, cr2) = enc.split_product(&cf);
        assert_eq!(c.cols(), 5);
        assert_eq!(cr1.len(), 4);
        assert_eq!(cr2.len(), 4);
        // checksum column ≈ row sums of C (exact up to fp error)
        for i in 0..4 {
            let rs: f64 = c.row(i).iter().sum();
            assert!((cr1[i] - rs).abs() < 1e-12);
        }
    }

    #[test]
    fn checksums_are_stored_in_input_precision() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let d = Distribution::normal_1_1();
        let b = Matrix::sample(16, 9, &d, &mut rng);
        let engine = GemmEngine::new(AccumModel::wide(Precision::Bf16));
        let r1 = r1_checksum_of_b(&b, &engine);
        for v in r1 {
            assert_eq!(Precision::Bf16.quantize(v), v);
        }
    }

    #[test]
    fn column_encoding_shape_and_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let enc = ColumnEncoding::encode_a(&a, &engine_f64());
        let ae = &enc.a_encoded;
        assert_eq!((ae.rows(), ae.cols()), (4, 2));
        assert_eq!(enc.m, 2);
        assert_eq!(enc.wide_rows(), 2);
        assert_eq!(ae.row(0), &[1.0, 2.0]);
        assert_eq!(ae.row(1), &[3.0, 4.0]);
        assert_eq!(ae.row(2), &[4.0, 6.0]); // column sums
        assert_eq!(ae.row(3), &[1.0 + 2.0 * 3.0, 2.0 + 2.0 * 4.0]); // weighted
    }

    #[test]
    fn routed_column_checksums_match_reference() {
        // The 2×M·M×K routing must be bitwise-identical to the
        // per-column engine.reduce / engine.dot loop on the
        // input-quantized columns — same contract as
        // routed_checksums_match_per_row_reference, transposed.
        let mut rng = Xoshiro256pp::seed_from_u64(19);
        let d = Distribution::normal_1_1();
        let a = Matrix::sample(21, 17, &d, &mut rng);
        let models = [
            AccumModel::cpu(Precision::F64),
            AccumModel::gpu_highprec(Precision::F64),
            AccumModel::cpu(Precision::F32),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::wide(Precision::Bf16),
            AccumModel::fp8(Precision::F8E4M3),
            AccumModel::cpu(Precision::Bf16),
            AccumModel {
                input: Precision::F16,
                work: Precision::F16,
                strategy: ReduceStrategy::Fma,
                out: Precision::F16,
            },
        ];
        for model in models {
            let engine = GemmEngine::new(model);
            let grid = offline_checksum_grid(&engine);
            let weights: Vec<f64> = (0..a.rows()).map(position_weight).collect();
            let mut col_q = vec![0.0; a.rows()];
            let enc = ColumnEncoding::encode_a(&a, &engine);
            for j in 0..a.cols() {
                for i in 0..a.rows() {
                    col_q[i] = model.input.quantize(a.get(i, j));
                }
                let want_c1 = grid.quantize(engine.reduce(&col_q));
                let want_c2 = grid.quantize(engine.dot(&col_q, &weights));
                assert_eq!(
                    enc.a_encoded.get(a.rows(), j).to_bits(),
                    want_c1.to_bits(),
                    "c1 col {j} diverged under {model:?}"
                );
                assert_eq!(
                    enc.a_encoded.get(a.rows() + 1, j).to_bits(),
                    want_c2.to_bits(),
                    "c2 col {j} diverged under {model:?}"
                );
            }
        }
    }

    #[test]
    fn column_split_roundtrip_on_grid_product() {
        // Full grid product (row + column encodings together): the
        // column-checksum rows of C^f must be consistent with column
        // sums of the data rows, and split_product must hand back the
        // original data region bitwise.
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let d = Distribution::uniform_pm1();
        let a = Matrix::sample(4, 8, &d, &mut rng);
        let b = Matrix::sample(8, 5, &d, &mut rng);
        let engine = engine_f64();
        let benc = ChecksumEncoding::encode_b(&b, &engine);
        let aenc = ColumnEncoding::encode_a(&a, &engine);
        let cf = engine
            .matmul_mixed_2d(&aenc.a_encoded, &benc.b_encoded, benc.wide_cols(), aenc.wide_rows())
            .c;
        assert_eq!((cf.rows(), cf.cols()), (6, 7));
        let (cr, cc1, cc2) = aenc.split_product(&cf);
        assert_eq!((cr.rows(), cr.cols()), (4, 7));
        let plain = engine.matmul_mixed(&a, &benc.b_encoded, benc.wide_cols()).c;
        for i in 0..4 {
            assert_eq!(cr.row(i), plain.row(i), "data row {i} perturbed by checksum rows");
        }
        // Column checksum ≈ column sums of C (exact up to fp error).
        for j in 0..5 {
            let cs: f64 = (0..4).map(|i| cr.get(i, j)).sum();
            let wcs: f64 = (0..4).map(|i| (i + 1) as f64 * cr.get(i, j)).sum();
            assert!((cc1[j] - cs).abs() < 1e-12);
            assert!((cc2[j] - wcs).abs() < 1e-12);
        }
    }
}
