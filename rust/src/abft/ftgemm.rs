//! Fault-tolerant GEMM: the public high-level API tying together encoding,
//! the modelled GEMM, adaptive thresholds, verification, localization,
//! correction and recomputation escalation.
//!
//! This is the Rust analogue of the FTAN-GEMM integration the paper
//! reports (§6.8): encode B once, run the encoded multiply, verify every
//! row against the adaptive threshold, correct single-event upsets in
//! place, and recompute rows whose syndrome is inconsistent with a single
//! upset.
//!
//! [`FtGemm`] is the single entry point: [`VerifyPolicy::granularity`]
//! selects between one verification over the whole K reduction
//! ([`VerifyGranularity::Monolithic`], `block_k = K`) and the paper's
//! §5.2 block-wise mode ([`VerifyGranularity::BlockK`]). Both are
//! parameterizations of the shared (private) `pipeline` module — the
//! detect/localize/correct/recompute stages are implemented exactly
//! once, there. [`crate::abft::PreparedWeights`] caches the weight-side
//! state for either granularity.

use crate::abft::encode::EncodingMode;
use crate::abft::pipeline;
use crate::abft::prepared::PreparedWeights;
use crate::error::Result;
use crate::gemm::{GemmEngine, GemmOutput};
use crate::matrix::Matrix;
use crate::threshold::Threshold;

/// How the K dimension is partitioned for verification (paper §5.2).
///
/// Granularity is a *verification* choice, not pure scheduling: blockwise
/// partials are aggregated with intermediate work-precision roundings, so
/// different granularities produce (legitimately) different bits — pick
/// one per workload. `BlockK` buys tighter per-block thresholds
/// (rounding-noise depth `bk` instead of `K`) and localizes faults in K.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VerifyGranularity {
    /// One verification over the whole K reduction (`block_k = K`).
    #[default]
    Monolithic,
    /// Partition K into tiles of this depth and checksum + verify each
    /// partial product independently before accumulating (the paper's
    /// NPU configuration uses 1024). Zero is treated as 1.
    BlockK(usize),
}

impl VerifyGranularity {
    /// The concrete K-block depth for a reduction of depth `k`.
    pub fn block_k_for(self, k: usize) -> usize {
        match self {
            VerifyGranularity::Monolithic => k.max(1),
            VerifyGranularity::BlockK(bk) => bk.max(1),
        }
    }
}

/// What the verification pipeline is allowed to do.
#[derive(Debug, Clone, Copy)]
pub struct VerifyPolicy {
    /// Verify the pre-quantization accumulator (fused-kernel / online
    /// ABFT, §3.6) instead of the stored output. ~1000× finer detection
    /// for low-precision GEMM.
    pub online: bool,
    /// Run detection inside the packed GEMM epilogue: the checksum
    /// dot-products and the |D1| > T comparison execute per output row as
    /// its C tile leaves the microkernel registers, before any output
    /// quantization (the paper's fused-kernel configuration). The epilogue
    /// applies the identical engine-scheduled reductions the post-hoc
    /// online verifier uses, so verdicts, reports and outputs are
    /// bitwise-unchanged — only *where* detection runs moves. Requires
    /// `online`; ignored when `online` is false.
    pub fused: bool,
    /// Attempt localization + in-place correction of flagged rows.
    pub correct: bool,
    /// Recompute rows whose syndrome cannot be corrected (inconsistent
    /// localization), using the engine.
    pub recompute: bool,
    /// Checksum geometry: row-only (classic Huang–Abraham, the default),
    /// row + A-side column checksums (`RowCol`), or the grid mode that
    /// iteratively peels row/column syndromes (`Grid`). Two-dimensional
    /// modes correct row-inconsistent multi-fault patterns (row bursts,
    /// checksum-column upsets) via the column direction before falling
    /// back to recompute; detection itself still runs on the row
    /// direction only, so recall and false-positive behaviour are
    /// unchanged. Orthogonal to [`crate::gemm::ReduceStrategy`].
    pub encoding: EncodingMode,
    /// Localization tolerance: the maximum accepted distance of the
    /// syndrome ratio D2/D1 from the nearest integer weight, in weight
    /// units.
    ///
    /// Derivation of the 0.45 default: a single upset of magnitude δ at
    /// column j gives D2/D1 = ((j+1)·δ + ε₂)/(δ + ε₁) = (j+1) + O(ε/δ),
    /// where ε are rounding-noise terms bounded (via the detection
    /// threshold T) by ε/δ < T·n/|D1| ≪ ½ for any fault worth
    /// correcting — so true single upsets land well inside any tolerance
    /// below 0.5. Conversely, integer weights are spaced exactly 1 apart:
    /// any `tol ≥ 0.5` makes *every* finite ratio round to some integer
    /// and localization can no longer reject multi-fault syndromes (the
    /// half-integer ratio of two equal-magnitude upsets at weights w₁,
    /// w₂ with w₁+w₂ odd sits exactly 0.5 from both neighbours). The
    /// default 0.45 is the accept-region maximum 0.5 with a 10% guard
    /// band against weighted-sum rounding noise: wide enough to accept
    /// every consistent single-upset ratio, tight enough that
    /// half-integer multi-fault ratios are always rejected as
    /// [`crate::abft::Localization::Inconsistent`].
    pub localize_tol: f64,
    /// Re-verify corrected rows and escalate to recompute if still flagged.
    pub reverify: bool,
    /// Severity-aware recovery (ApproxABFT-style): before escalating a
    /// detection to row recomputation, compare the residual |D1| against
    /// the output grid's quantization noise for that row
    /// (`u_out · Σ|row|`). When the residual is provably below it, the
    /// recompute could not change the quantized output meaningfully —
    /// the detection is *waived* ([`Verdict::Waived`]) and the
    /// tail-latency penalty of the escalation path is skipped. Detection
    /// itself is unaffected: every flagged row is still reported, so
    /// recall and false-positive behavior are bitwise-identical to the
    /// non-severity policy.
    pub severity: bool,
    /// How the K dimension is partitioned for verification: one
    /// monolithic check (the default) or the paper's §5.2 block-wise
    /// mode. See [`VerifyGranularity`].
    pub granularity: VerifyGranularity,
}

impl Default for VerifyPolicy {
    fn default() -> Self {
        VerifyPolicy {
            online: true,
            fused: false,
            correct: true,
            recompute: true,
            encoding: EncodingMode::RowOnly,
            localize_tol: 0.45,
            reverify: true,
            severity: false,
            granularity: VerifyGranularity::Monolithic,
        }
    }
}

impl VerifyPolicy {
    /// Offline (post-hoc) verification on the stored output — the
    /// debugging / spot-check configuration (§3.6 recommendations).
    pub fn offline() -> VerifyPolicy {
        VerifyPolicy { online: false, ..Default::default() }
    }

    /// Fused-epilogue verification: online detection executed inside the
    /// packed GEMM epilogue while each C tile is still in registers,
    /// pre-quantization (paper §3.6, the fused-kernel configuration).
    /// Decisions are bitwise-identical to the default online policy.
    pub fn fused() -> VerifyPolicy {
        VerifyPolicy { online: true, fused: true, ..Default::default() }
    }

    /// Detection only (no correction/recompute) — measurement
    /// configuration used by the FPR/DR experiments.
    pub fn detect_only(online: bool) -> VerifyPolicy {
        VerifyPolicy {
            online,
            fused: false,
            correct: false,
            recompute: false,
            encoding: EncodingMode::RowOnly,
            reverify: false,
            localize_tol: 0.45,
            severity: false,
            granularity: VerifyGranularity::Monolithic,
        }
    }

    /// Grid encoding with peeling multi-fault repair — the strongest
    /// correction mode ([`EncodingMode::Grid`]) on the default online
    /// policy.
    pub fn grid() -> VerifyPolicy {
        VerifyPolicy::default().with_encoding(EncodingMode::Grid)
    }

    /// The same policy with a different checksum geometry. Fused-epilogue
    /// detection only covers the row direction, so two-dimensional modes
    /// verify post-hoc (at the identical verification point — decisions
    /// are unchanged).
    pub fn with_encoding(mut self, encoding: EncodingMode) -> VerifyPolicy {
        self.encoding = encoding;
        self
    }

    /// The same policy with severity-aware recovery enabled: detections
    /// whose residual is provably below output-quantization noise skip
    /// the recompute escalation ([`Verdict::Waived`]).
    pub fn with_severity(mut self) -> VerifyPolicy {
        self.severity = true;
        self
    }

    /// The same policy at a different verification granularity (see
    /// [`VerifyGranularity`]).
    pub fn with_granularity(mut self, granularity: VerifyGranularity) -> VerifyPolicy {
        self.granularity = granularity;
        self
    }
}

/// Outcome of one protected multiply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// No row exceeded its threshold.
    Clean,
    /// All flagged rows were corrected in place.
    Corrected,
    /// All flagged rows were corrected in place, and at least one needed
    /// the column/grid direction (a row-inconsistent multi-fault pattern
    /// repaired without recomputation). Only produced by two-dimensional
    /// [`EncodingMode`]s.
    CorrectedGrid,
    /// Some rows required (or would require) recomputation.
    Recomputed,
    /// Faults detected but policy forbade repair.
    Flagged,
    /// Every detection was either corrected in place or waived by the
    /// severity policy (residual below output-quantization noise), and
    /// at least one was waived — no recomputation was spent.
    Waived,
}

/// One detected fault.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    /// Output row the fault was detected in.
    pub row: usize,
    /// Localized column, if the syndrome was consistent.
    pub col: Option<usize>,
    /// Verification difference D1 = rowsum − checksum (≈ fault magnitude).
    pub d1: f64,
    /// Weighted verification difference D2 (≈ w(j) · fault magnitude).
    pub d2: f64,
    /// The detection threshold |D1| was compared against.
    pub threshold: f64,
    /// Severity of the detection: `|D1| / threshold` (∞ when the
    /// threshold was zero or D1 non-finite). 1.0 is the detection floor;
    /// large values are exponent-class upsets.
    pub severity: f64,
    /// True if the row was corrected in place; false means recomputed,
    /// waived or left flagged.
    pub corrected: bool,
    /// True if the correction needed the column/grid direction (the row
    /// syndrome alone was inconsistent with a single upset). Always false
    /// under [`EncodingMode::RowOnly`].
    pub via_grid: bool,
    /// True if the severity policy waived this detection's recompute
    /// escalation (residual provably below output-quantization noise).
    pub waived: bool,
}

/// Verification report for one multiply.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// Collapsed outcome across every checked row.
    pub verdict: Verdict,
    /// Every row that exceeded its threshold.
    pub detections: Vec<Detection>,
    /// Rows verified (M per K-block).
    pub rows_checked: usize,
    /// Rows recomputed via the escalation path.
    pub rows_recomputed: usize,
    /// Detections whose recompute escalation the severity policy waived
    /// (always 0 unless [`VerifyPolicy::severity`] is set).
    pub rows_waived: usize,
    /// Rows whose repair needed the column/grid direction — corrected
    /// without recomputation where the row syndrome alone was
    /// inconsistent. Always 0 under [`EncodingMode::RowOnly`].
    pub rows_corrected_grid: usize,
    /// Row localizations that returned
    /// [`crate::abft::Localization::Inconsistent`] (multi-fault,
    /// checksum-column upset, or sub-noise fault) — the patterns that,
    /// without a two-dimensional encoding, fold straight into recompute.
    pub inconsistent_localizations: usize,
    /// Largest |D1| seen across every checked row (∞ if any row's D1 was
    /// non-finite). On a clean run this is the realized rounding-noise
    /// floor — the "Actual Diff" of the paper's tightness tables.
    pub max_abs_d1: f64,
    /// Smallest detection threshold issued across every checked row (∞
    /// when no rows were checked). `min_threshold / max_abs_d1` on a
    /// clean run is the realized threshold tightness.
    pub min_threshold: f64,
    /// Rows whose detection check executed inside the fused GEMM epilogue
    /// (equal to `rows_checked` under [`VerifyPolicy::fused`], 0
    /// otherwise).
    pub rows_fused: usize,
}

/// Output of [`FtGemm::multiply`].
#[derive(Debug, Clone)]
pub struct FtGemmOutput {
    /// The (possibly corrected) product, on the model's output grid.
    pub c: Matrix,
    /// What verification saw and did (across all K-blocks when the
    /// policy granularity is block-wise).
    pub report: VerifyReport,
    /// Which K-block each detection occurred in, parallel to
    /// `report.detections` (all zeros at monolithic granularity).
    pub detection_blocks: Vec<usize>,
    /// Number of K-blocks the multiply was verified in (1 at monolithic
    /// granularity).
    pub blocks: usize,
}

/// Fault-tolerant GEMM executor.
///
/// ```
/// use vabft::prelude::*;
///
/// let mut rng = Xoshiro256pp::seed_from_u64(1);
/// let d = Distribution::normal_1_1();
/// let a = Matrix::sample(16, 32, &d, &mut rng);
/// let b = Matrix::sample(32, 24, &d, &mut rng);
///
/// let ft = FtGemm::new(
///     GemmEngine::new(AccumModel::wide(Precision::Bf16)),
///     Box::new(VabftThreshold::default()),
///     VerifyPolicy::default(),
/// );
/// let out = ft.multiply(&a, &b).unwrap();
/// assert_eq!(out.report.verdict, Verdict::Clean);
/// assert_eq!((out.c.rows(), out.c.cols()), (16, 24));
/// ```
pub struct FtGemm {
    engine: GemmEngine,
    threshold: Box<dyn Threshold>,
    policy: VerifyPolicy,
}

impl FtGemm {
    /// Build an executor from an engine, a threshold algorithm and a
    /// verification policy.
    pub fn new(engine: GemmEngine, threshold: Box<dyn Threshold>, policy: VerifyPolicy) -> FtGemm {
        FtGemm { engine, threshold, policy }
    }

    /// The engine this executor runs on.
    pub fn engine(&self) -> &GemmEngine {
        &self.engine
    }

    /// The verification policy this executor applies.
    pub fn policy(&self) -> VerifyPolicy {
        self.policy
    }

    /// Precompute checksum encoding + threshold statistics for a weight
    /// matrix at the policy's verification granularity — the serving fast
    /// path: vLLM-style coordinators multiply thousands of activations
    /// against the same weights. See [`PreparedWeights`]. (The K depth of
    /// a [`VerifyGranularity::Monolithic`] handle is pinned at prepare
    /// time from `b.rows()`.)
    pub fn prepare(&self, b: &Matrix) -> PreparedWeights {
        match self.policy.granularity {
            VerifyGranularity::Monolithic => {
                PreparedWeights::prepare(b, &self.engine, &self.policy)
            }
            VerifyGranularity::BlockK(_) => {
                let bk = self.policy.granularity.block_k_for(b.rows());
                PreparedWeights::prepare_blockwise(b, &self.engine, &self.policy, bk)
            }
        }
    }

    /// Precompute weight-side state at an explicit `block_k` granularity
    /// (per-K-block encodings and statistics, paper §5.2), independent of
    /// the policy's own granularity.
    pub fn prepare_blockwise(&self, b: &Matrix, block_k: usize) -> PreparedWeights {
        PreparedWeights::prepare_blockwise(b, &self.engine, &self.policy, block_k)
    }

    /// Protected multiply: C = A·B with detection / correction per policy,
    /// at the policy's verification granularity. Under
    /// [`VerifyPolicy::fused`] the detection checks execute inside the
    /// packed GEMM epilogue rather than as a post-hoc sweep.
    pub fn multiply(&self, a: &Matrix, b: &Matrix) -> Result<FtGemmOutput> {
        let out = pipeline::run_blocks(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            b,
            self.policy.granularity.block_k_for(a.cols()),
            None::<fn(usize, &mut GemmOutput)>,
        )?;
        Ok(FtGemmOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }

    /// Protected multiply against prepared weights (serving hot path: no
    /// re-encoding, no O(K·N) statistics pass over B). Outputs and
    /// verification decisions are bitwise-identical to the cold path *at
    /// the handle's block granularity*: to [`FtGemm::multiply`] under the
    /// matching [`VerifyGranularity`] — blockwise partials are aggregated
    /// with intermediate work-precision roundings, so the two
    /// granularities legitimately differ from each other by O(u).
    ///
    /// `inject`, if given, is the experiment hook: it is invoked once per
    /// prepared K-block (once total for a monolithic handle) with the
    /// block index and the encoded partial product.
    pub fn multiply_prepared(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        inject: Option<&dyn Fn(usize, &mut GemmOutput)>,
    ) -> Result<FtGemmOutput> {
        let out = pipeline::run_prepared(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            w,
            inject.map(|f| move |bi: usize, o: &mut GemmOutput| f(bi, o)),
        )?;
        Ok(FtGemmOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }

    /// Protected multiply with fault injection between compute and verify
    /// (the experiment hook: `inject` mutates the encoded product; at
    /// block-wise granularity it fires once, on the first K-block).
    pub fn multiply_with_injection(
        &self,
        a: &Matrix,
        b: &Matrix,
        inject: impl FnOnce(&mut GemmOutput),
    ) -> Result<FtGemmOutput> {
        let mut inject = Some(inject);
        let out = pipeline::run_blocks(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            b,
            self.policy.granularity.block_k_for(a.cols()),
            Some(move |_bi: usize, o: &mut GemmOutput| {
                if let Some(f) = inject.take() {
                    f(o)
                }
            }),
        )?;
        Ok(FtGemmOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }

    /// Protected multiply with per-K-block fault injection:
    /// `inject(block_index, partial)` fires once per verified K-block
    /// (once total at monolithic granularity) — the block-attribution
    /// experiment hook.
    pub fn multiply_with_block_injection(
        &self,
        a: &Matrix,
        b: &Matrix,
        mut inject: impl FnMut(usize, &mut GemmOutput),
    ) -> Result<FtGemmOutput> {
        let out = pipeline::run_blocks(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            b,
            self.policy.granularity.block_k_for(a.cols()),
            Some(move |bi: usize, o: &mut GemmOutput| inject(bi, o)),
        )?;
        Ok(FtGemmOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }

    /// [`FtGemm::multiply_prepared`] under an explicit per-request policy
    /// (the protection-plan dispatch hook: one executor serves handles
    /// prepared under different planner schemes). The policy must be
    /// compatible with the handle — same model, verification point and
    /// encoding — exactly as [`crate::abft::PreparedWeights`] checks.
    pub fn multiply_prepared_with_policy(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        policy: &VerifyPolicy,
        inject: Option<&dyn Fn(usize, &mut GemmOutput)>,
    ) -> Result<FtGemmOutput> {
        let out = pipeline::run_prepared(
            &self.engine,
            self.threshold.as_ref(),
            policy,
            a,
            w,
            inject.map(|f| move |bi: usize, o: &mut GemmOutput| f(bi, o)),
        )?;
        Ok(FtGemmOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }

    /// Dual-compute replication against prepared weights: run the encoded
    /// multiply twice on the identical schedule, compare the two legs
    /// bitwise, and recover any divergent row by recomputation (policy
    /// permitting). No thresholds are consulted — the detector is exact
    /// equality of independent executions — and the clean-path output is
    /// bitwise-identical to [`FtGemm::multiply_prepared`] on the same
    /// handle (the first leg *is* that execution). `inject` corrupts only
    /// the first leg, mirroring a transient upset in one execution.
    pub fn multiply_replicated(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        inject: Option<&dyn Fn(usize, &mut GemmOutput)>,
    ) -> Result<FtGemmOutput> {
        self.multiply_replicated_with_policy(a, w, &self.policy, inject)
    }

    /// [`FtGemm::multiply_replicated`] under an explicit per-request
    /// policy (the planner's [`crate::planner::ProtectionScheme::Replicate`]
    /// dispatch path).
    pub fn multiply_replicated_with_policy(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        policy: &VerifyPolicy,
        inject: Option<&dyn Fn(usize, &mut GemmOutput)>,
    ) -> Result<FtGemmOutput> {
        let out = pipeline::run_replicated(
            &self.engine,
            policy,
            a,
            w,
            inject.map(|f| move |bi: usize, o: &mut GemmOutput| f(bi, o)),
        )?;
        Ok(FtGemmOutput {
            c: out.c,
            report: out.report,
            detection_blocks: out.detection_blocks,
            blocks: out.blocks,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;
    use crate::rng::{Distribution, Rng, Xoshiro256pp};
    use crate::threshold::VabftThreshold;

    fn ft(model: AccumModel, policy: VerifyPolicy) -> FtGemm {
        FtGemm::new(GemmEngine::new(model), Box::new(VabftThreshold::default()), policy)
    }

    fn operands(seed: u64, m: usize, k: usize, n: usize) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::normal_1_1();
        (Matrix::sample(m, k, &d, &mut rng), Matrix::sample(k, n, &d, &mut rng))
    }

    #[test]
    fn blockk_granularity_cold_and_warm_agree() {
        // BlockK(32) over K = 96 verifies three blocks; the cold path and
        // the prepared (warm) path must agree bit-for-bit — same
        // pipeline, same bits.
        let (a, b) = operands(6, 8, 96, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(
            model,
            VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(32)),
        );
        let out = g.multiply(&a, &b).unwrap();
        assert_eq!(out.blocks, 3);
        assert_eq!(out.report.verdict, Verdict::Clean);
        // Prepared path inherits the policy granularity too.
        let w = g.prepare(&b);
        let warm = g.multiply_prepared(&a, &w, None).unwrap();
        assert_eq!(warm.c.data(), out.c.data());
        assert_eq!(warm.blocks, 3);
    }

    #[test]
    fn blockwise_matches_monolithic_product() {
        let (a, b) = operands(1, 8, 96, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(32)));
        let out = g.multiply(&a, &b).unwrap();
        assert_eq!(out.report.verdict, Verdict::Clean);
        assert_eq!(out.blocks, 3);
        // numerically close to the monolithic engine result (different
        // accumulation grouping → small fp differences)
        let mono = GemmEngine::new(model).matmul(&a, &b);
        assert!(out.c.max_abs_diff(&mono.c) < 0.1, "{}", out.c.max_abs_diff(&mono.c));
    }

    #[test]
    fn ragged_last_block() {
        let (a, b) = operands(2, 4, 50, 8); // 50 = 32 + 18
        let model = AccumModel::cpu(Precision::F64);
        let g = ft(model, VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(32)));
        let out = g.multiply(&a, &b).unwrap();
        assert_eq!(out.blocks, 2);
        assert_eq!(out.report.verdict, Verdict::Clean);
        let mono = GemmEngine::new(model).matmul(&a, &b);
        assert!(out.c.max_abs_diff(&mono.c) < 1e-10);
    }

    #[test]
    fn fault_is_attributed_to_its_block_and_corrected() {
        let (a, b) = operands(3, 8, 128, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(64)));
        let clean = g.multiply(&a, &b).unwrap();
        let out = g
            .multiply_with_block_injection(&a, &b, |bi, acc| {
                if bi == 1 {
                    let v = acc.get(5, 3);
                    acc.set(5, 3, v + 8.0);
                }
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Corrected);
        assert_eq!(out.detection_blocks, vec![1], "fault must localize to block 1");
        assert_eq!(out.report.detections[0].row, 5);
        assert_eq!(out.report.detections[0].col, Some(3));
        assert!(out.c.max_abs_diff(&clean.c) < 1e-2);
    }

    #[test]
    fn per_block_thresholds_are_tighter_than_monolithic() {
        // The point of §5.2: depth-bk verification beats depth-K. Compare
        // the V-ABFT threshold of one block against the full-K threshold.
        use crate::threshold::{Threshold, ThresholdContext};
        let (a, b) = operands(4, 4, 1024, 64);
        let model = AccumModel::npu_fp32();
        let ctx = ThresholdContext::offline(model);
        let vab = VabftThreshold::default();
        let t_full = vab.thresholds(&a, &b, &ctx)[0];
        let a_blk = Matrix::from_fn(4, 128, |i, j| a.get(i, j));
        let b_blk = Matrix::from_fn(128, 64, |i, j| b.get(i, j));
        let t_blk = vab.thresholds(&a_blk, &b_blk, &ctx)[0];
        assert!(
            t_blk < t_full / 2.0,
            "block threshold {t_blk} should be ≪ full {t_full}"
        );
    }

    #[test]
    fn blockwise_results_independent_of_engine_parallelism() {
        // The unified pipeline runs on the tiled engine; per-block partials
        // (and hence thresholds, detections and outputs) must not depend on
        // the engine's thread count.
        use crate::gemm::ParallelismConfig;
        let (a, b) = operands(5, 6, 96, 12);
        let model = AccumModel::wide(Precision::Bf16);
        let policy = VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(32));
        let serial = ft(model, policy);
        let parallel = FtGemm::new(
            GemmEngine::with_parallelism(model, ParallelismConfig::with_threads(4)),
            Box::new(VabftThreshold::default()),
            policy,
        );
        let x = serial.multiply(&a, &b).unwrap();
        let y = parallel.multiply(&a, &b).unwrap();
        assert_eq!(x.c.data(), y.c.data(), "blockwise output must be thread-invariant");
        assert_eq!(x.report.verdict, y.report.verdict);
    }

    #[test]
    fn replication_clean_path_is_bitwise_identical_to_abft() {
        // Invariant #9's replication leg: the first replica *is* the
        // protected execution, so a clean replicated multiply returns the
        // exact bits of the ABFT path on the same handle.
        let (a, b) = operands(7, 8, 64, 16);
        for model in [AccumModel::wide(Precision::Bf16), AccumModel::npu_fp32()] {
            let g = ft(model, VerifyPolicy::default());
            let w = g.prepare(&b);
            let abft = g.multiply_prepared(&a, &w, None).unwrap();
            let rep = g.multiply_replicated(&a, &w, None).unwrap();
            assert_eq!(rep.c.data(), abft.c.data(), "{model:?}");
            assert_eq!(rep.report.verdict, Verdict::Clean);
            assert!(rep.report.detections.is_empty());
        }
    }

    #[test]
    fn replication_detects_and_recovers_injected_divergence() {
        let (a, b) = operands(8, 8, 64, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default());
        let w = g.prepare(&b);
        let clean = g.multiply_prepared(&a, &w, None).unwrap();
        // Data-element upset: detected, attributed to its column, and the
        // recovered output is bitwise the clean product.
        let inj = |_bi: usize, o: &mut GemmOutput| {
            let v = o.acc.get(3, 5);
            o.acc.set(3, 5, v + 4.0);
            o.c.set(3, 5, Precision::Bf16.quantize(v + 4.0));
        };
        let out = g.multiply_replicated(&a, &w, Some(&inj)).unwrap();
        assert_eq!(out.report.verdict, Verdict::Recomputed);
        assert_eq!(out.report.detections.len(), 1);
        assert_eq!(out.report.detections[0].row, 3);
        assert_eq!(out.report.detections[0].col, Some(5));
        assert_eq!(out.c.data(), clean.c.data(), "recovery must be exact");
        // Checksum-column upset (col n = 16 is C·e): still detected —
        // replication compares every encoded column — recall 1.0 on
        // checksum sites too.
        let inj_cs = |_bi: usize, o: &mut GemmOutput| {
            let v = o.acc.get(2, 16);
            o.acc.set(2, 16, v + 100.0);
        };
        let out = g.multiply_replicated(&a, &w, Some(&inj_cs)).unwrap();
        assert_ne!(out.report.verdict, Verdict::Clean);
        assert_eq!(out.report.detections[0].row, 2);
        assert_eq!(out.report.detections[0].col, None, "checksum site has no data column");
        assert_eq!(out.c.data(), clean.c.data());
    }

    #[test]
    fn replication_detect_only_flags_without_repair() {
        let (a, b) = operands(9, 4, 32, 8);
        let model = AccumModel::cpu(Precision::F64);
        let g = ft(model, VerifyPolicy::detect_only(true));
        let w = g.prepare(&b);
        let inj = |_bi: usize, o: &mut GemmOutput| {
            let v = o.acc.get(1, 2);
            o.acc.set(1, 2, v + 1.0);
            o.c.set(1, 2, v + 1.0);
        };
        let out = g.multiply_replicated(&a, &w, Some(&inj)).unwrap();
        assert_eq!(out.report.verdict, Verdict::Flagged);
        assert_eq!(out.report.rows_recomputed, 0);
    }

    #[test]
    fn clean_multiply_is_clean() {
        let (a, b) = operands(1, 16, 32, 24);
        for model in [
            AccumModel::cpu(Precision::F64),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::wide(Precision::Bf16),
        ] {
            let g = ft(model, VerifyPolicy::default());
            let out = g.multiply(&a, &b).unwrap();
            assert_eq!(out.report.verdict, Verdict::Clean, "{model:?}");
            assert!(out.report.detections.is_empty());
        }
    }

    #[test]
    fn injected_fault_corrected_online_bf16() {
        let (a, b) = operands(2, 8, 64, 32);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default());
        // Reference clean product for comparison.
        let clean = g.multiply(&a, &b).unwrap().c;
        let out = g
            .multiply_with_injection(&a, &b, |o| {
                // flip a large-ish amount at (3, 7) in the accumulator and
                // the stored C (a real upset corrupts the value wherever it
                // lives; we model output-register corruption).
                let v = o.acc.get(3, 7);
                o.acc.set(3, 7, v + 4.0);
                o.c.set(3, 7, Precision::Bf16.quantize(v + 4.0));
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Corrected);
        assert_eq!(out.report.detections.len(), 1);
        let d = &out.report.detections[0];
        assert_eq!(d.row, 3);
        assert_eq!(d.col, Some(7));
        // Corrected output matches the clean run everywhere.
        assert!(out.c.max_abs_diff(&clean) < 1e-6, "diff {}", out.c.max_abs_diff(&clean));
    }

    #[test]
    fn detect_only_policy_flags_without_touching() {
        let (a, b) = operands(3, 4, 16, 8);
        let model = AccumModel::cpu(Precision::F64);
        let g = ft(model, VerifyPolicy::detect_only(false));
        let out = g
            .multiply_with_injection(&a, &b, |o| {
                let v = o.c.get(1, 2);
                o.c.set(1, 2, v + 1.0);
                o.acc.set(1, 2, v + 1.0);
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Flagged);
        // value untouched
        assert!((out.c.get(1, 2) - (b.col_sums()[0] * 0.0 + out.c.get(1, 2))).abs() < 1e30);
    }

    #[test]
    fn checksum_column_fault_recomputes() {
        // Corrupt the checksum itself: D1 large but D2/D1 inconsistent →
        // recompute path.
        let (a, b) = operands(4, 4, 16, 8);
        let model = AccumModel::cpu(Precision::F64);
        let g = ft(model, VerifyPolicy::default());
        let clean = g.multiply(&a, &b).unwrap().c;
        let out = g
            .multiply_with_injection(&a, &b, |o| {
                // column n = 8 is C^{r1}
                let v = o.acc.get(2, 8);
                o.acc.set(2, 8, v + 100.0);
                o.c.set(2, 8, v + 100.0);
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Recomputed);
        assert_eq!(out.report.rows_recomputed, 1);
        assert!(out.c.max_abs_diff(&clean) < 1e-12);
    }

    #[test]
    fn many_random_seu_trials_all_recovered_fp32() {
        let model = AccumModel::gpu_highprec(Precision::F32);
        let g = ft(model, VerifyPolicy::default());
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut corrected = 0;
        let trials = 30;
        for t in 0..trials {
            let (a, b) = operands(100 + t, 8, 32, 16);
            let clean = g.multiply(&a, &b).unwrap().c;
            let fi = rng.uniform_u64(8) as usize;
            let fj = rng.uniform_u64(16) as usize;
            let mag = 0.5 + rng.next_f64() * 10.0;
            let out = g
                .multiply_with_injection(&a, &b, |o| {
                    let v = o.acc.get(fi, fj);
                    o.acc.set(fi, fj, v + mag);
                    o.c.set(fi, fj, Precision::F32.quantize(v + mag));
                })
                .unwrap();
            assert_ne!(out.report.verdict, Verdict::Clean, "trial {t} missed");
            assert!(
                out.c.max_abs_diff(&clean) < 1e-4,
                "trial {t}: repair failed, diff {}",
                out.c.max_abs_diff(&clean)
            );
            if out.report.verdict == Verdict::Corrected {
                corrected += 1;
            }
        }
        // The vast majority of clean SEUs should be corrected, not recomputed.
        assert!(corrected >= trials * 8 / 10, "only {corrected}/{trials} corrected");
    }
}
