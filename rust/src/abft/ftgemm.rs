//! Fault-tolerant GEMM: the public high-level API tying together encoding,
//! the modelled GEMM, adaptive thresholds, verification, localization,
//! correction and recomputation escalation.
//!
//! This is the Rust analogue of the FTAN-GEMM integration the paper
//! reports (§6.8): encode B once, run the encoded multiply, verify every
//! row against the adaptive threshold, correct single-event upsets in
//! place, and recompute rows whose syndrome is inconsistent with a single
//! upset.
//!
//! [`FtGemm`] is the monolithic (`block_k = K`) parameterization of the
//! shared pipeline in [`crate::abft::pipeline`];
//! [`crate::abft::BlockwiseFtGemm`] is the same pipeline at
//! `block_k = KC`. The detect/localize/correct/recompute stages are
//! implemented exactly once, there.

use crate::abft::encode::ChecksumEncoding;
use crate::abft::pipeline;
use crate::error::Result;
use crate::gemm::{GemmEngine, GemmOutput};
use crate::matrix::Matrix;
use crate::threshold::{PreparedBStats, Threshold, ThresholdContext};

/// A weight matrix prepared for repeated protected multiplies: checksum
/// encoding and threshold summary computed once (the serving fast path —
/// vLLM-style coordinators multiply thousands of activations against the
/// same weights).
#[derive(Debug, Clone)]
pub struct PreparedWeight {
    pub enc: ChecksumEncoding,
    pub stats: PreparedBStats,
}

/// What the verification pipeline is allowed to do.
#[derive(Debug, Clone, Copy)]
pub struct VerifyPolicy {
    /// Verify the pre-quantization accumulator (fused-kernel / online
    /// ABFT, §3.6) instead of the stored output. ~1000× finer detection
    /// for low-precision GEMM.
    pub online: bool,
    /// Attempt localization + in-place correction of flagged rows.
    pub correct: bool,
    /// Recompute rows whose syndrome cannot be corrected (inconsistent
    /// localization), using the engine.
    pub recompute: bool,
    /// Localization tolerance: max distance of D2/D1 from an integer.
    pub localize_tol: f64,
    /// Re-verify corrected rows and escalate to recompute if still flagged.
    pub reverify: bool,
}

impl Default for VerifyPolicy {
    fn default() -> Self {
        VerifyPolicy {
            online: true,
            correct: true,
            recompute: true,
            localize_tol: 0.45,
            reverify: true,
        }
    }
}

impl VerifyPolicy {
    /// Offline (post-hoc) verification on the stored output — the
    /// debugging / spot-check configuration (§3.6 recommendations).
    pub fn offline() -> VerifyPolicy {
        VerifyPolicy { online: false, ..Default::default() }
    }

    /// Detection only (no correction/recompute) — measurement
    /// configuration used by the FPR/DR experiments.
    pub fn detect_only(online: bool) -> VerifyPolicy {
        VerifyPolicy {
            online,
            correct: false,
            recompute: false,
            reverify: false,
            localize_tol: 0.45,
        }
    }
}

/// Outcome of one protected multiply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// No row exceeded its threshold.
    Clean,
    /// All flagged rows were corrected in place.
    Corrected,
    /// Some rows required (or would require) recomputation.
    Recomputed,
    /// Faults detected but policy forbade repair.
    Flagged,
}

/// One detected fault.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub row: usize,
    /// Localized column, if the syndrome was consistent.
    pub col: Option<usize>,
    pub d1: f64,
    pub d2: f64,
    pub threshold: f64,
    /// True if the row was corrected in place; false means recomputed or
    /// left flagged.
    pub corrected: bool,
}

/// Verification report for one multiply.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub verdict: Verdict,
    pub detections: Vec<Detection>,
    pub rows_checked: usize,
    pub rows_recomputed: usize,
}

/// Output of [`FtGemm::multiply`].
#[derive(Debug, Clone)]
pub struct FtGemmOutput {
    /// The (possibly corrected) product, on the model's output grid.
    pub c: Matrix,
    pub report: VerifyReport,
}

/// Fault-tolerant GEMM executor.
pub struct FtGemm {
    engine: GemmEngine,
    threshold: Box<dyn Threshold>,
    policy: VerifyPolicy,
}

impl FtGemm {
    pub fn new(engine: GemmEngine, threshold: Box<dyn Threshold>, policy: VerifyPolicy) -> FtGemm {
        FtGemm { engine, threshold, policy }
    }

    pub fn engine(&self) -> &GemmEngine {
        &self.engine
    }

    pub fn policy(&self) -> VerifyPolicy {
        self.policy
    }

    /// Encode a weight matrix for this executor's verification mode:
    /// online policies keep checksum columns in the FP32 datapath
    /// (fused-kernel ABFT), offline policies store them on the input grid.
    fn encode(&self, b: &Matrix) -> ChecksumEncoding {
        if self.policy.online {
            ChecksumEncoding::encode_b_wide(b, &self.engine)
        } else {
            ChecksumEncoding::encode_b(b, &self.engine)
        }
    }

    /// Precompute encoding + threshold summary for a weight matrix.
    pub fn prepare(&self, b: &Matrix) -> PreparedWeight {
        PreparedWeight { enc: self.encode(b), stats: PreparedBStats::of(b) }
    }

    /// Protected multiply: C = A·B with detection / correction per policy.
    pub fn multiply(&self, a: &Matrix, b: &Matrix) -> Result<FtGemmOutput> {
        self.multiply_with_injection(a, b, |_| {})
    }

    /// Protected multiply against a prepared weight (serving hot path: no
    /// re-encoding, no O(KN) statistics pass).
    pub fn multiply_prepared(
        &self,
        a: &Matrix,
        w: &PreparedWeight,
        inject: Option<&dyn Fn(&mut GemmOutput)>,
    ) -> Result<FtGemmOutput> {
        let mut out = self.engine.matmul_mixed(a, &w.enc.b_encoded, w.enc.wide_cols());
        if let Some(f) = inject {
            f(&mut out);
        }
        let thresholds = self.threshold.thresholds_prepared(a, &w.stats, &self.ctx());
        let weights = crate::abft::verify::weight_vector(w.enc.n);
        let bv = pipeline::verify_block(
            &self.engine,
            &self.policy,
            &w.enc,
            &thresholds,
            &weights,
            out,
            a,
            &w.stats.b,
        );
        let verdict = pipeline::verdict_of(&bv.detections, bv.rows_recomputed);
        let report = VerifyReport {
            verdict,
            rows_checked: a.rows(),
            rows_recomputed: bv.rows_recomputed,
            detections: bv.detections,
        };
        Ok(FtGemmOutput { c: pipeline::finalize(bv.part, &self.engine), report })
    }

    /// Protected multiply with fault injection between compute and verify
    /// (the experiment hook: `inject` mutates the encoded product).
    pub fn multiply_with_injection(
        &self,
        a: &Matrix,
        b: &Matrix,
        inject: impl FnOnce(&mut GemmOutput),
    ) -> Result<FtGemmOutput> {
        // Monolithic = the shared pipeline at block_k = K (one tile).
        let mut inject = Some(inject);
        let out = pipeline::run_blocks(
            &self.engine,
            self.threshold.as_ref(),
            &self.policy,
            a,
            b,
            a.cols().max(1),
            |_, o| {
                if let Some(f) = inject.take() {
                    f(o)
                }
            },
        )?;
        Ok(FtGemmOutput { c: out.c, report: out.report })
    }

    fn ctx(&self) -> ThresholdContext {
        pipeline::threshold_ctx(&self.engine, &self.policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;
    use crate::rng::{Distribution, Rng, Xoshiro256pp};
    use crate::threshold::VabftThreshold;

    fn ft(model: AccumModel, policy: VerifyPolicy) -> FtGemm {
        FtGemm::new(GemmEngine::new(model), Box::new(VabftThreshold::default()), policy)
    }

    fn operands(seed: u64, m: usize, k: usize, n: usize) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::normal_1_1();
        (Matrix::sample(m, k, &d, &mut rng), Matrix::sample(k, n, &d, &mut rng))
    }

    #[test]
    fn clean_multiply_is_clean() {
        let (a, b) = operands(1, 16, 32, 24);
        for model in [
            AccumModel::cpu(Precision::F64),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::wide(Precision::Bf16),
        ] {
            let g = ft(model, VerifyPolicy::default());
            let out = g.multiply(&a, &b).unwrap();
            assert_eq!(out.report.verdict, Verdict::Clean, "{model:?}");
            assert!(out.report.detections.is_empty());
        }
    }

    #[test]
    fn injected_fault_corrected_online_bf16() {
        let (a, b) = operands(2, 8, 64, 32);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default());
        // Reference clean product for comparison.
        let clean = g.multiply(&a, &b).unwrap().c;
        let out = g
            .multiply_with_injection(&a, &b, |o| {
                // flip a large-ish amount at (3, 7) in the accumulator and
                // the stored C (a real upset corrupts the value wherever it
                // lives; we model output-register corruption).
                let v = o.acc.get(3, 7);
                o.acc.set(3, 7, v + 4.0);
                o.c.set(3, 7, Precision::Bf16.quantize(v + 4.0));
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Corrected);
        assert_eq!(out.report.detections.len(), 1);
        let d = &out.report.detections[0];
        assert_eq!(d.row, 3);
        assert_eq!(d.col, Some(7));
        // Corrected output matches the clean run everywhere.
        assert!(out.c.max_abs_diff(&clean) < 1e-6, "diff {}", out.c.max_abs_diff(&clean));
    }

    #[test]
    fn detect_only_policy_flags_without_touching() {
        let (a, b) = operands(3, 4, 16, 8);
        let model = AccumModel::cpu(Precision::F64);
        let g = ft(model, VerifyPolicy::detect_only(false));
        let out = g
            .multiply_with_injection(&a, &b, |o| {
                let v = o.c.get(1, 2);
                o.c.set(1, 2, v + 1.0);
                o.acc.set(1, 2, v + 1.0);
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Flagged);
        // value untouched
        assert!((out.c.get(1, 2) - (b.col_sums()[0] * 0.0 + out.c.get(1, 2))).abs() < 1e30);
    }

    #[test]
    fn checksum_column_fault_recomputes() {
        // Corrupt the checksum itself: D1 large but D2/D1 inconsistent →
        // recompute path.
        let (a, b) = operands(4, 4, 16, 8);
        let model = AccumModel::cpu(Precision::F64);
        let g = ft(model, VerifyPolicy::default());
        let clean = g.multiply(&a, &b).unwrap().c;
        let out = g
            .multiply_with_injection(&a, &b, |o| {
                // column n = 8 is C^{r1}
                let v = o.acc.get(2, 8);
                o.acc.set(2, 8, v + 100.0);
                o.c.set(2, 8, v + 100.0);
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Recomputed);
        assert_eq!(out.report.rows_recomputed, 1);
        assert!(out.c.max_abs_diff(&clean) < 1e-12);
    }

    #[test]
    fn many_random_seu_trials_all_recovered_fp32() {
        let model = AccumModel::gpu_highprec(Precision::F32);
        let g = ft(model, VerifyPolicy::default());
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut corrected = 0;
        let trials = 30;
        for t in 0..trials {
            let (a, b) = operands(100 + t, 8, 32, 16);
            let clean = g.multiply(&a, &b).unwrap().c;
            let fi = rng.uniform_u64(8) as usize;
            let fj = rng.uniform_u64(16) as usize;
            let mag = 0.5 + rng.next_f64() * 10.0;
            let out = g
                .multiply_with_injection(&a, &b, |o| {
                    let v = o.acc.get(fi, fj);
                    o.acc.set(fi, fj, v + mag);
                    o.c.set(fi, fj, Precision::F32.quantize(v + mag));
                })
                .unwrap();
            assert_ne!(out.report.verdict, Verdict::Clean, "trial {t} missed");
            assert!(
                out.c.max_abs_diff(&clean) < 1e-4,
                "trial {t}: repair failed, diff {}",
                out.c.max_abs_diff(&clean)
            );
            if out.report.verdict == Verdict::Corrected {
                corrected += 1;
            }
        }
        // The vast majority of clean SEUs should be corrected, not recomputed.
        assert!(corrected >= trials * 8 / 10, "only {corrected}/{trials} corrected");
    }
}
