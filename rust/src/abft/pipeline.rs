//! The shared FT-GEMM verification pipeline.
//!
//! [`crate::abft::FtGemm`]'s monolithic and block-wise modes are two
//! parameterizations of the K-tiled pipeline in this module:
//!
//! * **monolithic** — `block_k = K`: one tile, one encode/verify pass
//!   (the classic Huang–Abraham shape);
//! * **blockwise** (paper §5.2) — `block_k = KC`: per-K-block checksum
//!   rows are carried through the same engine, each partial product is
//!   verified at reduction depth `bk` (tighter thresholds) and faults are
//!   additionally localized in K (which block).
//!
//! Per tile the pipeline runs detect → localize → correct → re-verify →
//! recompute with *one* implementation of each stage, then aggregates the
//! verified partials in the work precision and rounds to the output grid
//! once. The GEMMs themselves execute on the tiled parallel engine
//! ([`crate::gemm::tiled`]), whose schedule-preservation invariant is what
//! keeps every threshold valid here regardless of thread count.

use crate::abft::encode::{ChecksumEncoding, ColumnEncoding, EncodingMode};
use crate::abft::prepared::PreparedWeights;
use crate::abft::verify::{
    check_row, correct_in_place, localize, weight_vector, Localization, RowCheck,
};
use crate::abft::{Detection, Verdict, VerifyPolicy, VerifyReport};
use crate::error::Result;
use crate::gemm::{FusedProbe, FusedRowCheck, GemmEngine, GemmOutput};
use crate::matrix::Matrix;
use crate::threshold::{Threshold, ThresholdContext};

/// Result of a full pipeline run.
pub(crate) struct PipelineOutput {
    /// Aggregated (possibly repaired) product on the model's output grid.
    pub c: Matrix,
    pub report: VerifyReport,
    /// K-block index of each detection (parallel to `report.detections`).
    pub detection_blocks: Vec<usize>,
    pub blocks: usize,
}

/// Verified partial product of one K-block.
pub(crate) struct BlockVerify {
    /// The (possibly corrected/recomputed) data columns, on the verify
    /// grid (work precision online, output precision offline).
    pub part: Matrix,
    pub detections: Vec<Detection>,
    pub rows_recomputed: usize,
    /// Detections whose recompute the severity policy waived.
    pub rows_waived: usize,
    /// Rows repaired via the column/grid direction (no recompute spent).
    pub rows_corrected_grid: usize,
    /// Row localizations that came back [`Localization::Inconsistent`].
    pub inconsistent_localizations: usize,
    /// Largest |D1| across the block's rows (∞ on non-finite D1).
    pub max_abs_d1: f64,
    /// Smallest threshold issued across the block's rows.
    pub min_threshold: f64,
}

/// Column-direction repair context for two-dimensional encodings
/// ([`EncodingMode::RowCol`] / [`EncodingMode::Grid`]): the per-column
/// V-ABFT thresholds (via Cᵀ = Bᵀ·Aᵀ) plus the repair discipline. The
/// column checksums themselves travel as the bottom two rows of the
/// encoded product.
pub(crate) struct ColDirection {
    /// Per-column detection thresholds — the column direction's analogue
    /// of the per-row thresholds, same algorithm, transposed roles.
    pub thresholds: Vec<f64>,
    /// Grid mode: iterate peeling passes with incremental syndrome
    /// updates instead of RowCol's single column pass.
    pub peel: bool,
}

/// The threshold context matching a policy's verification point.
pub(crate) fn threshold_ctx(engine: &GemmEngine, policy: &VerifyPolicy) -> ThresholdContext {
    let model = engine.model();
    if policy.online {
        ThresholdContext::online(model)
    } else {
        ThresholdContext::offline(model)
    }
}

/// Verify one encoded (partial) product: per row, detect → localize →
/// correct (→ re-verify) → recompute, per the policy. `a_blk`/`b_blk` are
/// the operands that produced `out` (the full operands for the monolithic
/// case) and feed the recomputation escalation path. `weights` is the
/// position-weight vector of length `enc.n` (hoisted by callers: it
/// depends only on N, not on the block).
///
/// `fused`, when present, carries the per-row detection checks already
/// executed inside the GEMM epilogue (one entry per output row); the
/// pipeline then consumes those verdicts instead of re-running the
/// post-hoc sweep. The epilogue performs the identical engine-scheduled
/// arithmetic `check_row` would, so the two sources are bitwise-equal —
/// re-verification after an in-place correction always re-checks post-hoc
/// (the epilogue saw the pre-correction tile).
pub(crate) fn verify_block(
    engine: &GemmEngine,
    policy: &VerifyPolicy,
    enc: &ChecksumEncoding,
    thresholds: &[f64],
    weights: &[f64],
    fused: Option<&[FusedRowCheck]>,
    col: Option<&ColDirection>,
    out: GemmOutput,
    a_blk: &Matrix,
    b_blk: &Matrix,
) -> BlockVerify {
    let model = engine.model();
    // Online verification reads the accumulator; offline the stored C.
    let src = if policy.online { &out.acc } else { &out.c };
    let (mut part, cr1, cr2) = enc.split_product(src);
    let n = enc.n;
    debug_assert_eq!(weights.len(), n);
    // Precision the verified elements live on:
    let grid = if policy.online { model.work } else { model.out };
    // Two-dimensional encodings carry the column-checksum rows at the
    // bottom of the product: repair state, not data.
    let m_data = part.rows() - col.map_or(0, |_| 2);

    let mut detections: Vec<Detection> = Vec::new();
    let mut rows_recomputed = 0usize;
    let mut rows_waived = 0usize;
    let mut rows_corrected_grid = 0usize;
    let mut inconsistent_localizations = 0usize;
    let mut max_abs_d1 = 0.0f64;
    let mut min_threshold = f64::INFINITY;
    // Row pass: detect → localize → correct → re-verify. Rows the row
    // syndrome alone could not repair are deferred as (detection index,
    // residual) pairs; under a 2D encoding the column direction gets a
    // shot at them before the recompute/waive escalation.
    let mut pending: Vec<(usize, f64)> = Vec::new();
    for i in 0..m_data {
        let rc = match fused {
            Some(checks) => {
                let fc = checks[i];
                debug_assert_eq!(fc.row, i);
                RowCheck { d1: fc.d1, d2: fc.d2, threshold: fc.threshold, flagged: fc.flagged }
            }
            None => check_row(part.row(i), cr1[i], cr2[i], thresholds[i], engine, weights),
        };
        max_abs_d1 = max_abs_d1.max(if rc.d1.is_finite() { rc.d1.abs() } else { f64::INFINITY });
        min_threshold = min_threshold.min(rc.threshold);
        if !rc.flagged {
            continue;
        }
        let mut det = Detection {
            row: i,
            col: None,
            d1: rc.d1,
            d2: rc.d2,
            threshold: rc.threshold,
            severity: if rc.threshold > 0.0 && rc.d1.is_finite() {
                rc.d1.abs() / rc.threshold
            } else {
                f64::INFINITY
            },
            corrected: false,
            via_grid: false,
            waived: false,
        };
        // Residual error mass left in the row if no further repair runs:
        // the full discrepancy when uncorrected, the post-correction
        // re-verification difference when a correction failed to verify.
        let mut residual = rc.d1;
        if policy.correct {
            match localize(rc.d1, rc.d2, n, policy.localize_tol) {
                Localization::Column(j) => {
                    det.col = Some(j);
                    correct_in_place(&mut part, i, j, rc.d1, grid);
                    det.corrected = true;
                    residual = 0.0;
                    if policy.reverify {
                        let rc2 =
                            check_row(part.row(i), cr1[i], cr2[i], thresholds[i], engine, weights);
                        if rc2.flagged {
                            det.corrected = false; // correction didn't verify
                            residual = rc2.d1;
                        } else if col.is_some() && !(rc2.d2.abs() <= n as f64 * rc.threshold) {
                            // 2D-only: a near-integer multi-fault ratio can
                            // zero D1 while D2 still carries error mass —
                            // the weighted residual betrays the
                            // miscorrection, and the column direction can
                            // repair it. (Not applied under RowOnly, whose
                            // decisions stay bitwise-pinned to the 1D
                            // pipeline.)
                            det.corrected = false;
                            residual = rc2.d2;
                        }
                    }
                }
                Localization::Inconsistent => {
                    inconsistent_localizations += 1;
                }
            }
        }
        if !det.corrected {
            pending.push((detections.len(), residual));
        }
        detections.push(det);
    }

    // Column/grid repair: only reached when the row direction left work
    // undone, so clean runs and row-correctable single upsets never touch
    // it — the column syndromes are recovery state, not a detection
    // surface, which is what preserves the zero-FP contract by
    // construction.
    if let Some(cd) = col {
        if !pending.is_empty() && policy.correct {
            column_repair(
                engine,
                policy,
                cd,
                &mut part,
                m_data,
                n,
                &cr1,
                &cr2,
                thresholds,
                weights,
                grid,
                &mut detections,
                &mut pending,
                &mut rows_corrected_grid,
            );
        }
    }

    // Escalation for whatever is still unrepaired: severity waive or
    // recompute, exactly as the one-dimensional pipeline.
    for &(di, residual) in &pending {
        let det = &mut detections[di];
        if det.corrected {
            continue;
        }
        let i = det.row;
        if policy.recompute {
            // Severity-aware escalation: a recompute only changes the
            // *quantized* output if the residual clears the output grid's
            // own rounding noise for this row, u_out · Σ|row|. Below
            // that, the escalation is provably unobservable after output
            // quantization (ApproxABFT) — waive it. A non-finite
            // residual never satisfies the bound, so exponent-class
            // wreckage always recomputes.
            let noise =
                model.out.unit_roundoff() * part.row(i).iter().map(|v| v.abs()).sum::<f64>();
            if policy.severity && residual.abs() <= noise {
                det.waived = true;
                rows_waived += 1;
            } else {
                recompute_row(engine, policy, a_blk, b_blk, &mut part, i);
                rows_recomputed += 1;
            }
        }
    }
    BlockVerify {
        part,
        detections,
        rows_recomputed,
        rows_waived,
        rows_corrected_grid,
        inconsistent_localizations,
        max_abs_d1,
        min_threshold,
    }
}

/// Intersect row and column syndromes to repair multi-fault patterns the
/// row direction alone gave up on.
///
/// The column syndromes (plain and position-weighted, per data column,
/// against the A-side checksum rows riding at the bottom of the product)
/// are computed with the same engine-scheduled reductions `check_row`
/// uses — the column analogue at the identical verification point. Each
/// flagged column whose D2c/D1c ratio names a pending row repairs that
/// element (Eq. 10 transposed); grid mode then updates the syndromes
/// incrementally and iterates (peeling), which additionally unlocks
/// row-direction repairs of the residual single faults the column pass
/// exposed. A row only counts as repaired when an engine-checked
/// re-verification finds **both** its syndromes clean — miscorrections
/// cannot survive the gate, so soundness never depends on the peeling
/// heuristics.
///
/// Special case, checksum-fault certification: a pending row whose
/// weighted syndrome is clean (|D2| ≤ n·T, the weighted noise bound)
/// while *every* column syndrome is clean can only have been hit in its
/// C^{r1} checksum entry — the column code certifies the data intact and
/// the repair is to touch nothing (where RowOnly burns a full row
/// recompute; see `checksum_column_fault_recomputes`).
#[allow(clippy::too_many_arguments)]
fn column_repair(
    engine: &GemmEngine,
    policy: &VerifyPolicy,
    cd: &ColDirection,
    part: &mut Matrix,
    m_data: usize,
    n: usize,
    cr1: &[f64],
    cr2: &[f64],
    thresholds: &[f64],
    weights: &[f64],
    grid: crate::fp::Precision,
    detections: &mut [Detection],
    pending: &mut [(usize, f64)],
    rows_corrected_grid: &mut usize,
) {
    debug_assert_eq!(cd.thresholds.len(), n);
    // The product's bottom two rows hold the column checksums of the data
    // columns (the trailing entries of those rows are the unused corner).
    let cc1: Vec<f64> = part.row(m_data).to_vec();
    let cc2: Vec<f64> = part.row(m_data + 1).to_vec();
    let row_weights = weight_vector(m_data);
    let mut colbuf = vec![0.0f64; m_data];
    let mut d1c = vec![0.0f64; n];
    let mut d2c = vec![0.0f64; n];
    let mut any_col_flagged = false;
    for j in 0..n {
        for (i, slot) in colbuf.iter_mut().enumerate() {
            *slot = part.get(i, j);
        }
        d1c[j] = engine.reduce(&colbuf) - cc1[j];
        d2c[j] = engine.dot(&colbuf, &row_weights) - cc2[j];
        if !d1c[j].is_finite() || d1c[j].abs() > cd.thresholds[j] {
            any_col_flagged = true;
        }
    }

    if !any_col_flagged {
        // Checksum-fault certification (see the function docs).
        for p in pending.iter_mut() {
            let det = &mut detections[p.0];
            if det.corrected || det.col.is_some() {
                continue;
            }
            if det.d2.abs() <= n as f64 * det.threshold {
                det.corrected = true;
                det.via_grid = true;
                *rows_corrected_grid += 1;
                p.1 = 0.0;
            }
        }
        return; // nothing for the syndrome intersection to work on
    }

    // Peeling budget: RowCol gets exactly one column pass; Grid iterates
    // until a pass makes no progress (bounded well above any 2–4-flip
    // burst's worst case).
    let max_passes = if cd.peel { 2 + pending.len() + m_data.min(n) } else { 1 };
    for pass in 0..max_passes {
        let mut progress = false;
        // (a) Flagged columns whose syndrome ratio names a pending row
        // repair that element; incremental updates keep the column
        // syndromes current as elements are fixed.
        for j in 0..n {
            if !d1c[j].is_finite() || d1c[j].abs() <= cd.thresholds[j] {
                continue;
            }
            if let Localization::Column(r) = localize(d1c[j], d2c[j], m_data, policy.localize_tol)
            {
                let is_pending = pending
                    .iter()
                    .any(|&(di, _)| !detections[di].corrected && detections[di].row == r);
                if is_pending {
                    let delta = d1c[j];
                    correct_in_place(part, r, j, delta, grid);
                    d1c[j] -= delta;
                    d2c[j] -= row_weights[r] * delta;
                    progress = true;
                }
            }
        }
        if !progress && pass > 0 {
            break;
        }
        // (b) Close out pending rows. The acceptance gate is an
        // engine-checked row re-verification with both syndromes clean;
        // grid mode first peels a residual single fault the column
        // corrections may have exposed in the row direction.
        for p in pending.iter_mut() {
            if detections[p.0].corrected {
                continue;
            }
            let i = detections[p.0].row;
            let mut rc2 = check_row(part.row(i), cr1[i], cr2[i], thresholds[i], engine, weights);
            if cd.peel && rc2.flagged {
                if let Localization::Column(j) = localize(rc2.d1, rc2.d2, n, policy.localize_tol)
                {
                    correct_in_place(part, i, j, rc2.d1, grid);
                    d1c[j] -= rc2.d1;
                    d2c[j] -= row_weights[i] * rc2.d1;
                    progress = true;
                    rc2 = check_row(part.row(i), cr1[i], cr2[i], thresholds[i], engine, weights);
                }
            }
            p.1 = if rc2.flagged { rc2.d1 } else { rc2.d2 };
            if !rc2.flagged && rc2.d2.abs() <= n as f64 * thresholds[i] {
                let det = &mut detections[p.0];
                det.corrected = true;
                det.via_grid = true;
                *rows_corrected_grid += 1;
                p.1 = 0.0;
                progress = true;
            }
        }
        if !progress {
            break;
        }
    }
}

/// Recompute one row of a (partial) product — a 1×bk · bk×N GEMM — the
/// escalation path for syndromes inconsistent with a single upset.
pub(crate) fn recompute_row(
    engine: &GemmEngine,
    policy: &VerifyPolicy,
    a_blk: &Matrix,
    b_blk: &Matrix,
    part: &mut Matrix,
    row: usize,
) {
    let a_row = Matrix::from_vec(1, a_blk.cols(), a_blk.row(row).to_vec());
    let rec = engine.matmul(&a_row, b_blk);
    let src = if policy.online { rec.acc } else { rec.c };
    part.row_mut(row).copy_from_slice(src.row(0));
}

/// Collapse per-detection outcomes into the multiply's verdict.
pub(crate) fn verdict_of(detections: &[Detection], rows_recomputed: usize) -> Verdict {
    if detections.is_empty() {
        Verdict::Clean
    } else if rows_recomputed > 0 {
        Verdict::Recomputed
    } else if detections.iter().all(|d| d.corrected) {
        if detections.iter().any(|d| d.via_grid) {
            Verdict::CorrectedGrid
        } else {
            Verdict::Corrected
        }
    } else if detections.iter().all(|d| d.corrected || d.waived) {
        Verdict::Waived
    } else {
        Verdict::Flagged
    }
}

/// Finalize a verified accumulator: one rounding onto the output grid
/// (a no-op when the verify grid already equals the output grid).
pub(crate) fn finalize(acc: Matrix, engine: &GemmEngine) -> Matrix {
    acc.quantized(engine.model().out)
}

/// Run the K-tiled FT pipeline cold: prepare the weight-side state for
/// this call (per-block checksum encodings + statistics), then run the
/// prepared pipeline. Routing the cold path through [`run_prepared`] is
/// what makes the warm (weight-stationary) path bitwise-identical *by
/// construction* — there is exactly one execution path.
///
/// `inject(block_index, encoded_output)` is the experiment hook; it sees
/// the *encoded* partial product (data + checksum columns). `None` means
/// no injection — the distinction matters to the fused path, which can
/// only run detection inside the GEMM epilogue when nothing mutates the
/// product after the kernel returns.
pub(crate) fn run_blocks<F: FnMut(usize, &mut GemmOutput)>(
    engine: &GemmEngine,
    threshold: &dyn Threshold,
    policy: &VerifyPolicy,
    a: &Matrix,
    b: &Matrix,
    block_k: usize,
    inject: Option<F>,
) -> Result<PipelineOutput> {
    assert_eq!(
        a.cols(),
        b.rows(),
        "FT-GEMM shape mismatch {}x{} · {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    assert!(block_k > 0, "block_k must be positive");
    let w = PreparedWeights::prepare_blockwise(b, engine, policy, block_k);
    run_prepared(engine, threshold, policy, a, &w, inject)
}

/// Run the K-tiled FT pipeline against a [`PreparedWeights`] handle (the
/// weight-stationary warm path): per prepared K-block, execute the cached
/// encoded multiply, apply the injection hook, verify/correct/recompute
/// against the cached statistics, then aggregate verified partials in the
/// work precision and round once at the end.
///
/// Per-block thresholds are evaluated at the BLOCK reduction depth, so
/// e_max (and hence T) tightens with `block_k` exactly as on the cold
/// path. Shape or model/policy mismatches return an error.
///
/// Under a fused policy (`policy.fused && policy.online`) with no
/// injection hook, each block's detection checks execute inside the
/// packed GEMM epilogue via [`GemmEngine::matmul_mixed_fused`] — per row,
/// while the C tile leaves the registers and before any quantization.
/// With an injection hook the simulated upset lands *after* the kernel
/// returns, so the fused checks are re-swept over the mutated accumulator
/// with [`GemmEngine::fused_sweep`] — the identical arithmetic at the
/// identical verification point, which is what the experiment hook
/// models (a corrupted register visible to the epilogue's checker).
pub(crate) fn run_prepared<F: FnMut(usize, &mut GemmOutput)>(
    engine: &GemmEngine,
    threshold: &dyn Threshold,
    policy: &VerifyPolicy,
    a: &Matrix,
    w: &PreparedWeights,
    mut inject: Option<F>,
) -> Result<PipelineOutput> {
    w.check_compatible(engine, policy)?;
    crate::ensure!(
        a.cols() == w.k(),
        "FT-GEMM shape mismatch: A is {}x{}, prepared weights cover K = {}",
        a.rows(),
        a.cols(),
        w.k()
    );
    let (m, n) = (a.rows(), w.n());
    let model = engine.model();
    let ctx = *w.ctx();
    let blocks = w.num_blocks();
    // Position weights depend only on N — hoisted out of the block loop.
    let weights = weight_vector(n);
    // The fused epilogue covers the row direction only; two-dimensional
    // encodings verify post-hoc at the identical verification point
    // (pre-quantization accumulator), so decisions are unchanged.
    let fused_active = policy.fused && policy.online && policy.encoding == EncodingMode::RowOnly;
    let two_d = policy.encoding.two_dimensional();

    let mut acc = Matrix::zeros(m, n);
    let mut detections = Vec::new();
    let mut detection_blocks = Vec::new();
    let mut rows_recomputed = 0usize;
    let mut rows_waived = 0usize;
    let mut rows_corrected_grid = 0usize;
    let mut inconsistent_localizations = 0usize;
    let mut max_abs_d1 = 0.0f64;
    let mut min_threshold = f64::INFINITY;

    for (bi, blk) in w.blocks().iter().enumerate() {
        // Monolithic case: borrow A, no copy.
        let a_own;
        let a_blk: &Matrix = if blk.k0 == 0 && blk.k1 == w.k() {
            a
        } else {
            a_own = Matrix::from_fn(m, blk.k1 - blk.k0, |i, j| a.get(i, blk.k0 + j));
            &a_own
        };

        // Per-block thresholds from the cached B-side statistics; V-ABFT
        // skips its O(K·N) pass over B entirely here. Resolved before the
        // multiply so the fused epilogue can compare |D1| against T the
        // moment each row's tile leaves the registers.
        let thresholds = threshold.thresholds_prepared(a_blk, &blk.stats, &ctx);

        let (out, fused_checks, col) = if fused_active {
            let probe = FusedProbe { n, weights: &weights, thresholds: &thresholds };
            match inject.as_mut() {
                None => {
                    let (out, checks) = engine.matmul_mixed_fused(
                        a_blk,
                        &blk.enc.b_encoded,
                        blk.enc.wide_cols(),
                        &probe,
                    );
                    (out, Some(checks), None)
                }
                Some(f) => {
                    // The simulated upset mutates the product after the
                    // kernel returns; re-run the epilogue's checks over
                    // the mutated accumulator at the same verification
                    // point (pre-quantization, same arithmetic).
                    let mut out =
                        engine.matmul_mixed(a_blk, &blk.enc.b_encoded, blk.enc.wide_cols());
                    f(bi, &mut out);
                    let checks = engine.fused_sweep(&out.acc, &probe);
                    (out, Some(checks), None)
                }
            }
        } else if two_d {
            // A-side column checksums ride the packed operand exactly as
            // the B-side checksums do: the data rows keep their input
            // quantization and reduction schedule bitwise (the
            // matmul_mixed_2d contract), the two checksum rows come out
            // of the same kernel as two extra output rows.
            let cenc = if policy.online {
                ColumnEncoding::encode_a_wide(a_blk, engine)
            } else {
                ColumnEncoding::encode_a(a_blk, engine)
            };
            let mut out = engine.matmul_mixed_2d(
                &cenc.a_encoded,
                &blk.enc.b_encoded,
                blk.enc.wide_cols(),
                cenc.wide_rows(),
            );
            if let Some(f) = inject.as_mut() {
                f(bi, &mut out);
            }
            // Column-direction thresholds from the cached per-column B
            // statistics (transpose-role V-ABFT); the one-shot fallback is
            // bitwise-identical for handles prepared without them.
            let col_thresholds = match blk.col_stats.as_ref() {
                Some(cs) => threshold.thresholds_columns_prepared(a_blk, cs, &ctx),
                None => threshold.thresholds_columns(a_blk, &blk.stats.b, &ctx),
            };
            let cd = ColDirection {
                thresholds: col_thresholds,
                peel: policy.encoding == EncodingMode::Grid,
            };
            (out, None, Some(cd))
        } else {
            let mut out = engine.matmul_mixed(a_blk, &blk.enc.b_encoded, blk.enc.wide_cols());
            if let Some(f) = inject.as_mut() {
                f(bi, &mut out);
            }
            (out, None, None)
        };

        let bv = verify_block(
            engine,
            policy,
            &blk.enc,
            &thresholds,
            &weights,
            fused_checks.as_deref(),
            col.as_ref(),
            out,
            a_blk,
            &blk.stats.b,
        );

        rows_recomputed += bv.rows_recomputed;
        rows_waived += bv.rows_waived;
        rows_corrected_grid += bv.rows_corrected_grid;
        inconsistent_localizations += bv.inconsistent_localizations;
        max_abs_d1 = max_abs_d1.max(bv.max_abs_d1);
        min_threshold = min_threshold.min(bv.min_threshold);
        let tagged = detection_blocks.len() + bv.detections.len();
        detection_blocks.resize(tagged, bi);
        detections.extend(bv.detections);

        // Aggregate the verified partial into the running sum (work
        // precision; the single output rounding happens in finalize).
        // Batched: the row of raw sums is formed first, then rounded in
        // one quantize_slice pass — bitwise-identical to per-element
        // quantize(dv + sv), one format dispatch per row instead of per
        // element.
        for i in 0..m {
            let dst = acc.row_mut(i);
            for (dv, &sv) in dst.iter_mut().zip(bv.part.row(i)) {
                *dv += sv;
            }
            model.work.quantize_slice(dst);
        }
    }

    let verdict = verdict_of(&detections, rows_recomputed);
    let c = finalize(acc, engine);
    Ok(PipelineOutput {
        c,
        report: VerifyReport {
            verdict,
            detections,
            rows_checked: m * blocks,
            rows_recomputed,
            rows_waived,
            rows_corrected_grid,
            inconsistent_localizations,
            max_abs_d1,
            min_threshold,
            rows_fused: if fused_active { m * blocks } else { 0 },
        },
        detection_blocks,
        blocks,
    })
}

/// Dual-compute replication against a [`PreparedWeights`] handle: per
/// prepared K-block, execute the cached encoded multiply **twice** on the
/// identical schedule and compare the two legs bit-for-bit at the
/// policy's verification point (pre-quantization accumulator online,
/// stored C offline). Any divergent element is a detection; divergent
/// rows are recovered by recomputation (policy permitting), then verified
/// partials aggregate exactly as [`run_prepared`] aggregates them.
///
/// Properties the planner and the campaign rely on:
///
/// * **Clean path is bitwise the ABFT path.** The first leg runs the
///   same `matmul_mixed` call, injection hook and aggregation loop as
///   [`run_prepared`]'s staged path; the second leg and the comparison
///   read but never write. A clean replicated multiply therefore returns
///   the exact bits of the staged ABFT multiply on the same handle —
///   replication is a pure verifier swap (invariant #9).
/// * **No thresholds.** The detector is exact inequality of two
///   executions of a deterministic schedule, so the false-positive rate
///   is structurally zero and detection covers *every* encoded column —
///   including the checksum columns ABFT can only certify indirectly
///   (`col` is `None` for a divergence in a checksum column).
/// * **Fused policies run staged.** Replication has no epilogue checks
///   to fuse; the comparison is the verification.
///
/// `inject` corrupts only the first leg — the model of a transient upset
/// in one of two independent executions.
pub(crate) fn run_replicated<F: FnMut(usize, &mut GemmOutput)>(
    engine: &GemmEngine,
    policy: &VerifyPolicy,
    a: &Matrix,
    w: &PreparedWeights,
    mut inject: Option<F>,
) -> Result<PipelineOutput> {
    w.check_compatible(engine, policy)?;
    crate::ensure!(
        policy.encoding == EncodingMode::RowOnly,
        "replication verifies by bitwise comparison; prepare the handle RowOnly \
         (two-dimensional encodings add repair state replication never consults)"
    );
    crate::ensure!(
        a.cols() == w.k(),
        "FT-GEMM shape mismatch: A is {}x{}, prepared weights cover K = {}",
        a.rows(),
        a.cols(),
        w.k()
    );
    let (m, n) = (a.rows(), w.n());
    let model = engine.model();
    let blocks = w.num_blocks();

    let mut acc = Matrix::zeros(m, n);
    let mut detections: Vec<Detection> = Vec::new();
    let mut detection_blocks = Vec::new();
    let mut rows_recomputed = 0usize;
    let mut max_abs_d1 = 0.0f64;

    for (bi, blk) in w.blocks().iter().enumerate() {
        let a_own;
        let a_blk: &Matrix = if blk.k0 == 0 && blk.k1 == w.k() {
            a
        } else {
            a_own = Matrix::from_fn(m, blk.k1 - blk.k0, |i, j| a.get(i, blk.k0 + j));
            &a_own
        };

        // Leg 1: the protected execution (the injection hook lands here).
        let mut leg = engine.matmul_mixed(a_blk, &blk.enc.b_encoded, blk.enc.wide_cols());
        if let Some(f) = inject.as_mut() {
            f(bi, &mut leg);
        }
        // Leg 2: the shadow execution — same operands, same schedule.
        let shadow = engine.matmul_mixed(a_blk, &blk.enc.b_encoded, blk.enc.wide_cols());

        // Compare at the policy's verification point, over every encoded
        // column. Bit comparison via to_bits: plain `!=` would miss
        // nothing here (identical schedules cannot produce +0.0 vs -0.0)
        // but would treat two identical NaN payloads as divergent.
        let (src, ref_src) = if policy.online {
            (&leg.acc, &shadow.acc)
        } else {
            (&leg.c, &shadow.c)
        };
        let wide = src.cols();
        let mut divergent_rows: Vec<usize> = Vec::new();
        for i in 0..m {
            let mut first: Option<(usize, f64)> = None;
            for j in 0..wide {
                let (x, y) = (src.get(i, j), ref_src.get(i, j));
                if x.to_bits() != y.to_bits() {
                    let d = x - y;
                    max_abs_d1 =
                        max_abs_d1.max(if d.is_finite() { d.abs() } else { f64::INFINITY });
                    if first.is_none() {
                        first = Some((j, d));
                    }
                }
            }
            if let Some((j, d)) = first {
                divergent_rows.push(i);
                detections.push(Detection {
                    row: i,
                    col: if j < n { Some(j) } else { None },
                    d1: d,
                    d2: 0.0,
                    threshold: 0.0,
                    severity: f64::INFINITY,
                    corrected: false,
                    via_grid: false,
                    waived: false,
                });
                detection_blocks.push(bi);
            }
        }

        let (mut part, _cr1, _cr2) = blk.enc.split_product(src);
        if policy.recompute {
            for &i in &divergent_rows {
                recompute_row(engine, policy, a_blk, &blk.stats.b, &mut part, i);
                rows_recomputed += 1;
            }
        }

        // Aggregate exactly as run_prepared does (bitwise-identical loop).
        for i in 0..m {
            let dst = acc.row_mut(i);
            for (dv, &sv) in dst.iter_mut().zip(part.row(i)) {
                *dv += sv;
            }
            model.work.quantize_slice(dst);
        }
    }

    let verdict = verdict_of(&detections, rows_recomputed);
    let c = finalize(acc, engine);
    Ok(PipelineOutput {
        c,
        report: VerifyReport {
            verdict,
            detections,
            rows_checked: m * blocks,
            rows_recomputed,
            rows_waived: 0,
            rows_corrected_grid: 0,
            inconsistent_localizations: 0,
            max_abs_d1,
            min_threshold: f64::INFINITY,
            rows_fused: 0,
        },
        detection_blocks,
        blocks,
    })
}
