//! The shared FT-GEMM verification pipeline.
//!
//! [`crate::abft::FtGemm`] and [`crate::abft::BlockwiseFtGemm`] used to be
//! two divergent code paths; they are now two parameterizations of the
//! K-tiled pipeline in this module:
//!
//! * **monolithic** — `block_k = K`: one tile, one encode/verify pass
//!   (the classic Huang–Abraham shape);
//! * **blockwise** (paper §5.2) — `block_k = KC`: per-K-block checksum
//!   rows are carried through the same engine, each partial product is
//!   verified at reduction depth `bk` (tighter thresholds) and faults are
//!   additionally localized in K (which block).
//!
//! Per tile the pipeline runs detect → localize → correct → re-verify →
//! recompute with *one* implementation of each stage, then aggregates the
//! verified partials in the work precision and rounds to the output grid
//! once. The GEMMs themselves execute on the tiled parallel engine
//! ([`crate::gemm::tiled`]), whose schedule-preservation invariant is what
//! keeps every threshold valid here regardless of thread count.

use crate::abft::encode::ChecksumEncoding;
use crate::abft::prepared::PreparedWeights;
use crate::abft::verify::{
    check_row, correct_in_place, localize, weight_vector, Localization, RowCheck,
};
use crate::abft::{Detection, Verdict, VerifyPolicy, VerifyReport};
use crate::error::Result;
use crate::gemm::{FusedProbe, FusedRowCheck, GemmEngine, GemmOutput};
use crate::matrix::Matrix;
use crate::threshold::{Threshold, ThresholdContext};

/// Result of a full pipeline run.
pub(crate) struct PipelineOutput {
    /// Aggregated (possibly repaired) product on the model's output grid.
    pub c: Matrix,
    pub report: VerifyReport,
    /// K-block index of each detection (parallel to `report.detections`).
    pub detection_blocks: Vec<usize>,
    pub blocks: usize,
}

/// Verified partial product of one K-block.
pub(crate) struct BlockVerify {
    /// The (possibly corrected/recomputed) data columns, on the verify
    /// grid (work precision online, output precision offline).
    pub part: Matrix,
    pub detections: Vec<Detection>,
    pub rows_recomputed: usize,
    /// Detections whose recompute the severity policy waived.
    pub rows_waived: usize,
    /// Largest |D1| across the block's rows (∞ on non-finite D1).
    pub max_abs_d1: f64,
    /// Smallest threshold issued across the block's rows.
    pub min_threshold: f64,
}

/// The threshold context matching a policy's verification point.
pub(crate) fn threshold_ctx(engine: &GemmEngine, policy: &VerifyPolicy) -> ThresholdContext {
    let model = engine.model();
    if policy.online {
        ThresholdContext::online(model)
    } else {
        ThresholdContext::offline(model)
    }
}

/// Verify one encoded (partial) product: per row, detect → localize →
/// correct (→ re-verify) → recompute, per the policy. `a_blk`/`b_blk` are
/// the operands that produced `out` (the full operands for the monolithic
/// case) and feed the recomputation escalation path. `weights` is the
/// position-weight vector of length `enc.n` (hoisted by callers: it
/// depends only on N, not on the block).
///
/// `fused`, when present, carries the per-row detection checks already
/// executed inside the GEMM epilogue (one entry per output row); the
/// pipeline then consumes those verdicts instead of re-running the
/// post-hoc sweep. The epilogue performs the identical engine-scheduled
/// arithmetic `check_row` would, so the two sources are bitwise-equal —
/// re-verification after an in-place correction always re-checks post-hoc
/// (the epilogue saw the pre-correction tile).
pub(crate) fn verify_block(
    engine: &GemmEngine,
    policy: &VerifyPolicy,
    enc: &ChecksumEncoding,
    thresholds: &[f64],
    weights: &[f64],
    fused: Option<&[FusedRowCheck]>,
    out: GemmOutput,
    a_blk: &Matrix,
    b_blk: &Matrix,
) -> BlockVerify {
    let model = engine.model();
    // Online verification reads the accumulator; offline the stored C.
    let src = if policy.online { &out.acc } else { &out.c };
    let (mut part, cr1, cr2) = enc.split_product(src);
    let n = enc.n;
    debug_assert_eq!(weights.len(), n);
    // Precision the verified elements live on:
    let grid = if policy.online { model.work } else { model.out };

    let mut detections = Vec::new();
    let mut rows_recomputed = 0usize;
    let mut rows_waived = 0usize;
    let mut max_abs_d1 = 0.0f64;
    let mut min_threshold = f64::INFINITY;
    for i in 0..part.rows() {
        let rc = match fused {
            Some(checks) => {
                let fc = checks[i];
                debug_assert_eq!(fc.row, i);
                RowCheck { d1: fc.d1, d2: fc.d2, threshold: fc.threshold, flagged: fc.flagged }
            }
            None => check_row(part.row(i), cr1[i], cr2[i], thresholds[i], engine, weights),
        };
        max_abs_d1 = max_abs_d1.max(if rc.d1.is_finite() { rc.d1.abs() } else { f64::INFINITY });
        min_threshold = min_threshold.min(rc.threshold);
        if !rc.flagged {
            continue;
        }
        let mut det = Detection {
            row: i,
            col: None,
            d1: rc.d1,
            d2: rc.d2,
            threshold: rc.threshold,
            severity: if rc.threshold > 0.0 && rc.d1.is_finite() {
                rc.d1.abs() / rc.threshold
            } else {
                f64::INFINITY
            },
            corrected: false,
            waived: false,
        };
        // Residual error mass left in the row if no further repair runs:
        // the full discrepancy when uncorrected, the post-correction
        // re-verification difference when a correction failed to verify.
        let mut residual = rc.d1;
        if policy.correct {
            if let Localization::Column(j) = localize(rc.d1, rc.d2, n, policy.localize_tol) {
                det.col = Some(j);
                correct_in_place(&mut part, i, j, rc.d1, grid);
                det.corrected = true;
                residual = 0.0;
                if policy.reverify {
                    let rc2 =
                        check_row(part.row(i), cr1[i], cr2[i], thresholds[i], engine, weights);
                    if rc2.flagged {
                        det.corrected = false; // correction didn't verify
                        residual = rc2.d1;
                    }
                }
            }
        }
        if !det.corrected && policy.recompute {
            // Severity-aware escalation: a recompute only changes the
            // *quantized* output if the residual clears the output grid's
            // own rounding noise for this row, u_out · Σ|row|. Below
            // that, the escalation is provably unobservable after output
            // quantization (ApproxABFT) — waive it. A non-finite
            // residual never satisfies the bound, so exponent-class
            // wreckage always recomputes.
            let noise = model.out.unit_roundoff()
                * part.row(i).iter().map(|v| v.abs()).sum::<f64>();
            if policy.severity && residual.abs() <= noise {
                det.waived = true;
                rows_waived += 1;
            } else {
                recompute_row(engine, policy, a_blk, b_blk, &mut part, i);
                rows_recomputed += 1;
            }
        }
        detections.push(det);
    }
    BlockVerify { part, detections, rows_recomputed, rows_waived, max_abs_d1, min_threshold }
}

/// Recompute one row of a (partial) product — a 1×bk · bk×N GEMM — the
/// escalation path for syndromes inconsistent with a single upset.
pub(crate) fn recompute_row(
    engine: &GemmEngine,
    policy: &VerifyPolicy,
    a_blk: &Matrix,
    b_blk: &Matrix,
    part: &mut Matrix,
    row: usize,
) {
    let a_row = Matrix::from_vec(1, a_blk.cols(), a_blk.row(row).to_vec());
    let rec = engine.matmul(&a_row, b_blk);
    let src = if policy.online { rec.acc } else { rec.c };
    part.row_mut(row).copy_from_slice(src.row(0));
}

/// Collapse per-detection outcomes into the multiply's verdict.
pub(crate) fn verdict_of(detections: &[Detection], rows_recomputed: usize) -> Verdict {
    if detections.is_empty() {
        Verdict::Clean
    } else if rows_recomputed > 0 {
        Verdict::Recomputed
    } else if detections.iter().all(|d| d.corrected) {
        Verdict::Corrected
    } else if detections.iter().all(|d| d.corrected || d.waived) {
        Verdict::Waived
    } else {
        Verdict::Flagged
    }
}

/// Finalize a verified accumulator: one rounding onto the output grid
/// (a no-op when the verify grid already equals the output grid).
pub(crate) fn finalize(acc: Matrix, engine: &GemmEngine) -> Matrix {
    acc.quantized(engine.model().out)
}

/// Run the K-tiled FT pipeline cold: prepare the weight-side state for
/// this call (per-block checksum encodings + statistics), then run the
/// prepared pipeline. Routing the cold path through [`run_prepared`] is
/// what makes the warm (weight-stationary) path bitwise-identical *by
/// construction* — there is exactly one execution path.
///
/// `inject(block_index, encoded_output)` is the experiment hook; it sees
/// the *encoded* partial product (data + checksum columns). `None` means
/// no injection — the distinction matters to the fused path, which can
/// only run detection inside the GEMM epilogue when nothing mutates the
/// product after the kernel returns.
pub(crate) fn run_blocks<F: FnMut(usize, &mut GemmOutput)>(
    engine: &GemmEngine,
    threshold: &dyn Threshold,
    policy: &VerifyPolicy,
    a: &Matrix,
    b: &Matrix,
    block_k: usize,
    inject: Option<F>,
) -> Result<PipelineOutput> {
    assert_eq!(
        a.cols(),
        b.rows(),
        "FT-GEMM shape mismatch {}x{} · {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    assert!(block_k > 0, "block_k must be positive");
    let w = PreparedWeights::prepare_blockwise(b, engine, policy, block_k);
    run_prepared(engine, threshold, policy, a, &w, inject)
}

/// Run the K-tiled FT pipeline against a [`PreparedWeights`] handle (the
/// weight-stationary warm path): per prepared K-block, execute the cached
/// encoded multiply, apply the injection hook, verify/correct/recompute
/// against the cached statistics, then aggregate verified partials in the
/// work precision and round once at the end.
///
/// Per-block thresholds are evaluated at the BLOCK reduction depth, so
/// e_max (and hence T) tightens with `block_k` exactly as on the cold
/// path. Shape or model/policy mismatches return an error.
///
/// Under a fused policy (`policy.fused && policy.online`) with no
/// injection hook, each block's detection checks execute inside the
/// packed GEMM epilogue via [`GemmEngine::matmul_mixed_fused`] — per row,
/// while the C tile leaves the registers and before any quantization.
/// With an injection hook the simulated upset lands *after* the kernel
/// returns, so the fused checks are re-swept over the mutated accumulator
/// with [`GemmEngine::fused_sweep`] — the identical arithmetic at the
/// identical verification point, which is what the experiment hook
/// models (a corrupted register visible to the epilogue's checker).
pub(crate) fn run_prepared<F: FnMut(usize, &mut GemmOutput)>(
    engine: &GemmEngine,
    threshold: &dyn Threshold,
    policy: &VerifyPolicy,
    a: &Matrix,
    w: &PreparedWeights,
    mut inject: Option<F>,
) -> Result<PipelineOutput> {
    w.check_compatible(engine, policy)?;
    crate::ensure!(
        a.cols() == w.k(),
        "FT-GEMM shape mismatch: A is {}x{}, prepared weights cover K = {}",
        a.rows(),
        a.cols(),
        w.k()
    );
    let (m, n) = (a.rows(), w.n());
    let model = engine.model();
    let ctx = *w.ctx();
    let blocks = w.num_blocks();
    // Position weights depend only on N — hoisted out of the block loop.
    let weights = weight_vector(n);
    let fused_active = policy.fused && policy.online;

    let mut acc = Matrix::zeros(m, n);
    let mut detections = Vec::new();
    let mut detection_blocks = Vec::new();
    let mut rows_recomputed = 0usize;
    let mut rows_waived = 0usize;
    let mut max_abs_d1 = 0.0f64;
    let mut min_threshold = f64::INFINITY;

    for (bi, blk) in w.blocks().iter().enumerate() {
        // Monolithic case: borrow A, no copy.
        let a_own;
        let a_blk: &Matrix = if blk.k0 == 0 && blk.k1 == w.k() {
            a
        } else {
            a_own = Matrix::from_fn(m, blk.k1 - blk.k0, |i, j| a.get(i, blk.k0 + j));
            &a_own
        };

        // Per-block thresholds from the cached B-side statistics; V-ABFT
        // skips its O(K·N) pass over B entirely here. Resolved before the
        // multiply so the fused epilogue can compare |D1| against T the
        // moment each row's tile leaves the registers.
        let thresholds = threshold.thresholds_prepared(a_blk, &blk.stats, &ctx);

        let (out, fused_checks) = if fused_active {
            let probe = FusedProbe { n, weights: &weights, thresholds: &thresholds };
            match inject.as_mut() {
                None => {
                    let (out, checks) = engine.matmul_mixed_fused(
                        a_blk,
                        &blk.enc.b_encoded,
                        blk.enc.wide_cols(),
                        &probe,
                    );
                    (out, Some(checks))
                }
                Some(f) => {
                    // The simulated upset mutates the product after the
                    // kernel returns; re-run the epilogue's checks over
                    // the mutated accumulator at the same verification
                    // point (pre-quantization, same arithmetic).
                    let mut out =
                        engine.matmul_mixed(a_blk, &blk.enc.b_encoded, blk.enc.wide_cols());
                    f(bi, &mut out);
                    let checks = engine.fused_sweep(&out.acc, &probe);
                    (out, Some(checks))
                }
            }
        } else {
            let mut out = engine.matmul_mixed(a_blk, &blk.enc.b_encoded, blk.enc.wide_cols());
            if let Some(f) = inject.as_mut() {
                f(bi, &mut out);
            }
            (out, None)
        };

        let bv = verify_block(
            engine,
            policy,
            &blk.enc,
            &thresholds,
            &weights,
            fused_checks.as_deref(),
            out,
            a_blk,
            &blk.stats.b,
        );

        rows_recomputed += bv.rows_recomputed;
        rows_waived += bv.rows_waived;
        max_abs_d1 = max_abs_d1.max(bv.max_abs_d1);
        min_threshold = min_threshold.min(bv.min_threshold);
        let tagged = detection_blocks.len() + bv.detections.len();
        detection_blocks.resize(tagged, bi);
        detections.extend(bv.detections);

        // Aggregate the verified partial into the running sum (work
        // precision; the single output rounding happens in finalize).
        // Batched: the row of raw sums is formed first, then rounded in
        // one quantize_slice pass — bitwise-identical to per-element
        // quantize(dv + sv), one format dispatch per row instead of per
        // element.
        for i in 0..m {
            let dst = acc.row_mut(i);
            for (dv, &sv) in dst.iter_mut().zip(bv.part.row(i)) {
                *dv += sv;
            }
            model.work.quantize_slice(dst);
        }
    }

    let verdict = verdict_of(&detections, rows_recomputed);
    let c = finalize(acc, engine);
    Ok(PipelineOutput {
        c,
        report: VerifyReport {
            verdict,
            detections,
            rows_checked: m * blocks,
            rows_recomputed,
            rows_waived,
            max_abs_d1,
            min_threshold,
            rows_fused: if fused_active { m * blocks } else { 0 },
        },
        detection_blocks,
        blocks,
    })
}
