//! Weight-stationary serving state: [`PreparedWeights`].
//!
//! In inference serving the weight matrix B is reused across every request
//! while the activations A change per request. The cold FT-GEMM path
//! re-derives, per call, (a) B's checksum encoding (two engine-scheduled
//! reductions per row of B, §2.2), (b) the V-ABFT B-side statistics
//! (max/min/mean per K-block, Algorithm 1) and (c) the threshold context —
//! all of which depend only on B, the accumulation model and the
//! verification point. [`PreparedWeights`] computes those once, with the
//! **same rounding schedule** as the live path, so every calibrated e_max
//! stays valid and the warm path is bitwise-identical to the cold path in
//! both outputs and verification decisions.
//!
//! This converts per-request `O(K·N · requests)` encode work into `O(K·N)`
//! once per weight registration — the amortization argument of
//! arithmetic-intensity-guided fault tolerance applied to the serving
//! north star.
//!
//! The handle is block-granular: prepared at `block_k = K` it drives the
//! monolithic [`crate::abft::FtGemm`] path, prepared at `block_k = KC` it
//! drives [`crate::abft::VerifyGranularity::BlockK`] verification with
//! per-K-block encodings and statistics (paper §5.2), each block verified
//! at its own (tighter) reduction depth.
//!
//! ```
//! use vabft::prelude::*;
//!
//! let mut rng = Xoshiro256pp::seed_from_u64(7);
//! let d = Distribution::Normal { mean: 0.0, std: 1.0 };
//! let a = Matrix::sample(8, 64, &d, &mut rng);
//! let b = Matrix::sample(64, 32, &d, &mut rng);
//!
//! let ft = FtGemm::new(
//!     GemmEngine::new(AccumModel::wide(Precision::Bf16)),
//!     Box::new(VabftThreshold::default()),
//!     VerifyPolicy::default(),
//! );
//! let cold = ft.multiply(&a, &b).unwrap();
//! let w = ft.prepare(&b); // encode + statistics, once
//! let warm = ft.multiply_prepared(&a, &w, None).unwrap();
//! assert_eq!(cold.c.data(), warm.c.data()); // bitwise-identical
//! assert_eq!(cold.report.verdict, warm.report.verdict);
//! ```

use crate::abft::encode::{ChecksumEncoding, EncodingMode};
use crate::abft::pipeline;
use crate::abft::VerifyPolicy;
use crate::error::Result;
use crate::gemm::{AccumModel, GemmEngine};
use crate::matrix::Matrix;
use crate::threshold::{BSummary, PreparedBStats, PreparedColStats, ThresholdContext};

/// One K-block of a prepared weight matrix: its checksum encoding plus the
/// statistics the threshold algorithms consume.
#[derive(Debug, Clone)]
pub struct PreparedBlock {
    /// First K index covered by this block (inclusive).
    pub k0: usize,
    /// One past the last K index covered by this block.
    pub k1: usize,
    /// `[B_blk | B_blk·r1 | B_blk·r2]`, encoded under the engine's
    /// schedule; checksum columns on the grid the verification policy
    /// dictates (work precision online, input/output grid offline).
    pub enc: ChecksumEncoding,
    /// The block's data plus its one-pass V-ABFT summary (Σ|μ|, Σμ², Σσ²
    /// with the extrema bound) — what [`crate::threshold::Threshold::thresholds_prepared`]
    /// consumes.
    pub stats: PreparedBStats,
    /// Column-direction statistics (per-column stats of this B block, the
    /// "rows of Bᵀ" role in Cᵀ = Bᵀ·Aᵀ) — what
    /// [`crate::threshold::Threshold::thresholds_columns_prepared`]
    /// consumes. Only populated when the handle was prepared for a
    /// two-dimensional [`EncodingMode`]; `None` under `RowOnly`.
    pub col_stats: Option<PreparedColStats>,
}

/// A weight matrix prepared once for repeated protected multiplies — the
/// weight-stationary serving fast path.
///
/// Holds, per K-block of granularity `block_k`:
///
/// * the ABFT column-checksum encoding of B (so no per-request encode),
/// * the V-ABFT B-side statistics (so the per-request threshold cost is
///   `O(M·K)` over A only, not `O(K·N)` over B),
/// * and the resolved [`ThresholdContext`] for the accumulation model and
///   verification point it was prepared under.
///
/// Everything is computed with the same engine-scheduled arithmetic as the
/// cold path, so warm-path outputs and detect/localize decisions are
/// **bitwise-identical** to encode-per-call — guaranteed structurally: the
/// cold pipeline itself routes through a freshly-prepared handle.
///
/// A handle is valid for any engine with the same [`AccumModel`] and any
/// [`crate::gemm::ParallelismConfig`] (schedule preservation), but is tied
/// to the verification point (`policy.online`) it was prepared for;
/// [`PreparedWeights::check_compatible`] enforces both.
#[derive(Debug, Clone)]
pub struct PreparedWeights {
    blocks: Vec<PreparedBlock>,
    k: usize,
    n: usize,
    block_k: usize,
    model: AccumModel,
    online: bool,
    encoding: EncodingMode,
    ctx: ThresholdContext,
    protection: Option<crate::planner::PlanEntry>,
}

impl PreparedWeights {
    /// Prepare a weight matrix at monolithic granularity (`block_k = K`,
    /// one encoding/statistics block — the [`crate::abft::FtGemm`] shape).
    pub fn prepare(b: &Matrix, engine: &GemmEngine, policy: &VerifyPolicy) -> PreparedWeights {
        Self::prepare_blockwise(b, engine, policy, b.rows().max(1))
    }

    /// Prepare a weight matrix at `block_k` granularity: one checksum
    /// encoding and one statistics summary per K-block, matching the
    /// blockwise pipeline's tiling (paper §5.2). Each block's thresholds
    /// are later evaluated at the block's own reduction depth.
    pub fn prepare_blockwise(
        b: &Matrix,
        engine: &GemmEngine,
        policy: &VerifyPolicy,
        block_k: usize,
    ) -> PreparedWeights {
        assert!(block_k > 0, "block_k must be positive");
        let (k, n) = (b.rows(), b.cols());
        let blocks_count = (k + block_k - 1) / block_k;
        let mut blocks = Vec::with_capacity(blocks_count);
        for bi in 0..blocks_count {
            let k0 = bi * block_k;
            let k1 = (k0 + block_k).min(k);
            // The slice must be built exactly as the live pipeline builds
            // it, so the encodings cover bit-for-bit the same operand.
            // Owning the block (one O(K·N) copy, also paid by the cold
            // path that prepares per call) is the price of a handle with
            // no lifetime ties: the copy feeds the recompute-escalation
            // operand and the non-V-ABFT threshold fallback.
            let b_blk = if k0 == 0 && k1 == k {
                b.clone()
            } else {
                Matrix::from_fn(k1 - k0, n, |i, j| b.get(k0 + i, j))
            };
            let enc = if policy.online {
                ChecksumEncoding::encode_b_wide(&b_blk, engine)
            } else {
                ChecksumEncoding::encode_b(&b_blk, engine)
            };
            let bsum = BSummary::of(&b_blk);
            // Column-direction stats only when a 2D encoding will consume
            // them: the extra transpose pass is wasted work under RowOnly.
            let col_stats = if policy.encoding.two_dimensional() {
                Some(PreparedColStats::of(&b_blk))
            } else {
                None
            };
            blocks.push(PreparedBlock {
                k0,
                k1,
                enc,
                stats: PreparedBStats { b: b_blk, bsum },
                col_stats,
            });
        }
        PreparedWeights {
            blocks,
            k,
            n,
            block_k,
            model: engine.model(),
            online: policy.online,
            encoding: policy.encoding,
            ctx: pipeline::threshold_ctx(engine, policy),
            protection: None,
        }
    }

    /// Attach a protection-plan entry: the planner's scheme decision rides
    /// the weight handle, so workers dispatch per request without ever
    /// re-consulting the planner. Scheduling metadata only — the encodings
    /// and statistics are untouched.
    pub fn with_protection(mut self, entry: crate::planner::PlanEntry) -> PreparedWeights {
        self.protection = Some(entry);
        self
    }

    /// The protection-plan entry riding this handle, if one was attached
    /// at registration ([`PreparedWeights::with_protection`]).
    pub fn protection(&self) -> Option<&crate::planner::PlanEntry> {
        self.protection.as_ref()
    }

    /// K (rows of the prepared weight matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// N (columns of the prepared weight matrix).
    pub fn n(&self) -> usize {
        self.n
    }

    /// The K-block granularity this handle was prepared at.
    pub fn block_k(&self) -> usize {
        self.block_k
    }

    /// Number of K-blocks (`ceil(K / block_k)`).
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The per-block encodings and statistics.
    pub fn blocks(&self) -> &[PreparedBlock] {
        &self.blocks
    }

    /// The resolved threshold context (accumulation model + verification
    /// point) the handle was prepared under.
    pub fn ctx(&self) -> &ThresholdContext {
        &self.ctx
    }

    /// The accumulation model the encodings were computed under.
    pub fn model(&self) -> AccumModel {
        self.model
    }

    /// True if prepared for online (pre-quantization accumulator)
    /// verification; false for offline.
    pub fn online(&self) -> bool {
        self.online
    }

    /// The [`EncodingMode`] the handle was prepared for. Two-dimensional
    /// modes carry per-block column statistics; `RowOnly` handles do not,
    /// so the mode is part of the compatibility contract.
    pub fn encoding(&self) -> EncodingMode {
        self.encoding
    }

    /// Approximate resident size in bytes (data + encodings + statistics)
    /// — useful for sizing the coordinator's weight cache.
    pub fn bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|blk| {
                (blk.enc.b_encoded.data().len() + blk.stats.b.data().len())
                    * std::mem::size_of::<f64>()
            })
            .sum()
    }

    /// Verify this handle matches an executor's accumulation model and
    /// verification point. The encodings depend on both: a mismatch would
    /// silently change what the checksums cover, so it is an error rather
    /// than a recompute.
    pub fn check_compatible(&self, engine: &GemmEngine, policy: &VerifyPolicy) -> Result<()> {
        crate::ensure!(
            self.model == engine.model(),
            "PreparedWeights model mismatch: prepared under {:?}, engine runs {:?}",
            self.model,
            engine.model()
        );
        crate::ensure!(
            self.online == policy.online,
            "PreparedWeights verification-point mismatch: prepared online={}, policy online={}",
            self.online,
            policy.online
        );
        crate::ensure!(
            self.encoding == policy.encoding,
            "PreparedWeights encoding mismatch: prepared {:?}, policy wants {:?}",
            self.encoding,
            policy.encoding
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::{FtGemm, Verdict, VerifyGranularity};
    use crate::fp::Precision;
    use crate::gemm::ReduceStrategy;
    use crate::rng::{Distribution, Xoshiro256pp};
    use crate::threshold::VabftThreshold;

    fn operands(seed: u64, m: usize, k: usize, n: usize) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::normal_1_1();
        (Matrix::sample(m, k, &d, &mut rng), Matrix::sample(k, n, &d, &mut rng))
    }

    fn ft(model: AccumModel, policy: VerifyPolicy) -> FtGemm {
        FtGemm::new(GemmEngine::new(model), Box::new(VabftThreshold::default()), policy)
    }

    #[test]
    fn warm_path_is_bitwise_identical_all_strategies() {
        let (a, b) = operands(1, 8, 96, 24);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let model = AccumModel {
                input: Precision::Bf16,
                work: Precision::F32,
                strategy,
                out: Precision::Bf16,
            };
            for policy in [VerifyPolicy::default(), VerifyPolicy::offline()] {
                let g = ft(model, policy);
                let cold = g.multiply(&a, &b).unwrap();
                let w = g.prepare(&b);
                let warm = g.multiply_prepared(&a, &w, None).unwrap();
                assert_eq!(cold.c.data(), warm.c.data(), "{strategy:?} online={}", policy.online);
                assert_eq!(cold.report.verdict, warm.report.verdict);
                assert_eq!(cold.report.detections.len(), warm.report.detections.len());
            }
        }
    }

    #[test]
    fn warm_blockwise_is_bitwise_identical() {
        let (a, b) = operands(2, 6, 100, 16); // ragged: 100 = 3×32 + 4
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(32)));
        let cold = g.multiply(&a, &b).unwrap();
        let w = g.prepare(&b);
        assert_eq!(w.num_blocks(), 4);
        assert_eq!(w.block_k(), 32);
        let warm = g.multiply_prepared(&a, &w, None).unwrap();
        assert_eq!(cold.c.data(), warm.c.data());
        assert_eq!(cold.report.verdict, warm.report.verdict);
        assert_eq!(cold.blocks, warm.blocks);
    }

    #[test]
    fn protection_entry_rides_the_handle() {
        let (_, b) = operands(8, 1, 32, 16);
        let engine = GemmEngine::new(AccumModel::wide(Precision::Bf16));
        let w = PreparedWeights::prepare(&b, &engine, &VerifyPolicy::default());
        assert!(w.protection().is_none());
        let entry = crate::planner::PlanEntry {
            weight: 3,
            name: "attn.qkv".to_string(),
            m: 4,
            k: 32,
            n: 16,
            intensity: crate::planner::arithmetic_intensity(4, 32, 16),
            scheme: crate::planner::ProtectionScheme::Fused,
            predicted_ns: 123.0,
        };
        let w = w.with_protection(entry);
        let got = w.protection().expect("entry attached");
        assert_eq!(got.weight, 3);
        assert_eq!(got.scheme, crate::planner::ProtectionScheme::Fused);
    }

    #[test]
    fn warm_path_detection_decisions_match_cold_under_injection() {
        let (a, b) = operands(3, 8, 64, 32);
        let model = AccumModel::wide(Precision::Bf16);
        let g = ft(model, VerifyPolicy::default());
        let inject = |o: &mut crate::gemm::GemmOutput| {
            let v = o.acc.get(3, 7);
            o.acc.set(3, 7, v + 4.0);
            o.c.set(3, 7, Precision::Bf16.quantize(v + 4.0));
        };
        let cold = g.multiply_with_injection(&a, &b, inject).unwrap();
        let w = g.prepare(&b);
        let inj: &dyn Fn(usize, &mut crate::gemm::GemmOutput) = &|_, o| inject(o);
        let warm = g.multiply_prepared(&a, &w, Some(inj)).unwrap();
        assert_eq!(cold.report.verdict, Verdict::Corrected);
        assert_eq!(warm.report.verdict, Verdict::Corrected);
        assert_eq!(cold.report.detections.len(), warm.report.detections.len());
        assert_eq!(cold.report.detections[0].row, warm.report.detections[0].row);
        assert_eq!(cold.report.detections[0].col, warm.report.detections[0].col);
        assert_eq!(cold.c.data(), warm.c.data());
    }

    #[test]
    fn prepared_blocks_cover_k_exactly() {
        let (_, b) = operands(4, 1, 70, 8);
        let engine = GemmEngine::new(AccumModel::cpu(Precision::F64));
        let w = PreparedWeights::prepare_blockwise(&b, &engine, &VerifyPolicy::default(), 32);
        assert_eq!(w.num_blocks(), 3);
        assert_eq!(w.k(), 70);
        assert_eq!(w.n(), 8);
        let spans: Vec<(usize, usize)> = w.blocks().iter().map(|bl| (bl.k0, bl.k1)).collect();
        assert_eq!(spans, vec![(0, 32), (32, 64), (64, 70)]);
        assert!(w.bytes() > 0);
    }

    #[test]
    fn incompatible_engine_or_policy_is_rejected() {
        let (a, b) = operands(5, 4, 32, 16);
        let g_online = ft(AccumModel::wide(Precision::Bf16), VerifyPolicy::default());
        let w = g_online.prepare(&b);
        // Same weights, offline executor: verification point mismatch.
        let g_offline = ft(AccumModel::wide(Precision::Bf16), VerifyPolicy::offline());
        assert!(g_offline.multiply_prepared(&a, &w, None).is_err());
        // Different accumulation model: encoding grid mismatch.
        let g_f64 = ft(AccumModel::cpu(Precision::F64), VerifyPolicy::default());
        assert!(g_f64.multiply_prepared(&a, &w, None).is_err());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let (_, b) = operands(6, 1, 32, 16);
        let g = ft(AccumModel::wide(Precision::Bf16), VerifyPolicy::default());
        let w = g.prepare(&b);
        let (a_bad, _) = operands(7, 4, 48, 16);
        assert!(g.multiply_prepared(&a_bad, &w, None).is_err());
    }
}
