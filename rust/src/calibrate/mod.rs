//! e_max determination: recommended values, scaling models and the
//! one-time calibration protocol (paper §3.6, Tables 1/2/7).
//!
//! e_max is the maximum relative verification error of a platform's GEMM,
//! defined empirically as `max |E| / |checksum|` over calibration trials.
//! §3.6's key insight: e_max is governed by the **accumulation and output
//! precision**, not the input precision — BF16/FP16/FP8 GEMMs with FP32
//! internal accumulation all behave as "one output rounding", giving
//! e_max ≈ 2·u_output independent of K, while FP32 per-step accumulation
//! gives e_max ∝ √K.

use crate::fp::Precision;
use crate::gemm::{AccumModel, GemmEngine, ReduceStrategy};
use crate::matrix::Matrix;
use crate::rng::{Distribution, Rng, Xoshiro256pp};

/// Scaling law of e_max with the reduction length n.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EmaxModel {
    /// e_max independent of n.
    Constant(f64),
    /// e_max = coeff·√n + offset (the GPU FP32/FP64 and NPU FP32 law).
    SqrtN { coeff: f64, offset: f64 },
}

impl EmaxModel {
    /// Evaluate at reduction length `n`.
    pub fn eval(&self, n: usize) -> f64 {
        match *self {
            EmaxModel::Constant(c) => c,
            EmaxModel::SqrtN { coeff, offset } => coeff * (n as f64).sqrt() + offset,
        }
    }

    /// Human-readable form of the law.
    pub fn label(&self) -> String {
        match *self {
            EmaxModel::Constant(c) => format!("{c:.2e}"),
            EmaxModel::SqrtN { coeff, offset } => format!("{coeff:.2e}·√N + {offset:.2e}"),
        }
    }
}

/// The platforms whose accumulation behaviour the paper measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    /// Intel Xeon: FMA/SIMD tree reduction → constant e_max.
    Cpu,
    /// NVIDIA H100: per-step rounding for FP32/FP64 (√N), wide accumulation
    /// for BF16/FP16/FP8 (constant 2u_out).
    Gpu,
    /// Ascend 910B: wide accumulation for BF16/FP16, per-step FP32 (√N).
    Npu,
}

impl Platform {
    /// Display name of the platform ("CPU (Xeon)" etc.).
    pub fn name(self) -> &'static str {
        match self {
            Platform::Cpu => "CPU (Xeon)",
            Platform::Gpu => "GPU (H100)",
            Platform::Npu => "NPU (910B)",
        }
    }

    /// The accumulation model this platform uses for a given operand
    /// precision (DESIGN.md §3 substitution table).
    pub fn model_for(self, p: Precision) -> AccumModel {
        match (self, p) {
            (Platform::Cpu, _) => AccumModel::cpu(p),
            (Platform::Gpu, Precision::F64) | (Platform::Gpu, Precision::F32) => {
                AccumModel::gpu_highprec(p)
            }
            (Platform::Npu, Precision::F64) | (Platform::Npu, Precision::F32) => {
                AccumModel::gpu_highprec(p)
            }
            (_, Precision::F8E4M3) | (_, Precision::F8E5M2) => AccumModel::fp8(p),
            (_, low) => AccumModel::wide(low),
        }
    }
}

/// Recommended e_max values (paper Table 7) plus the rule for arbitrary
/// models. `lookup` is what the production threshold path uses.
#[derive(Debug, Clone, Default)]
pub struct EmaxTable;

impl EmaxTable {
    /// Table 7 rows, as (platform, precision) → model.
    pub fn recommended(platform: Platform, p: Precision) -> EmaxModel {
        match (platform, p) {
            (Platform::Cpu, Precision::F64) => EmaxModel::Constant(6e-16),
            (Platform::Cpu, Precision::F32) => EmaxModel::Constant(4e-7),
            (Platform::Gpu, Precision::F64) => {
                EmaxModel::SqrtN { coeff: 1.0e-17, offset: 2.5e-16 }
            }
            (Platform::Gpu, Precision::F32) => {
                EmaxModel::SqrtN { coeff: 5.0e-9, offset: 1.2e-7 }
            }
            (Platform::Gpu, Precision::Bf16) | (Platform::Npu, Precision::Bf16) => {
                EmaxModel::Constant(8e-3)
            }
            (Platform::Gpu, Precision::F16) | (Platform::Npu, Precision::F16) => {
                EmaxModel::Constant(1e-3)
            }
            // §3.6: FP8's effective e_max equals the FP16 value (FP16 output).
            (_, Precision::F8E4M3) | (_, Precision::F8E5M2) => EmaxModel::Constant(1e-3),
            // Table 1/7: NPU FP32 = 2e-6·√(N/1024) = 6.25e-8·√N
            (Platform::Npu, Precision::F32) => {
                EmaxModel::SqrtN { coeff: 2e-6 / 32.0, offset: 0.0 }
            }
            (Platform::Npu, Precision::F64) => {
                // Not measured in the paper; use the GPU FP64 law.
                EmaxModel::SqrtN { coeff: 1.0e-17, offset: 2.5e-16 }
            }
            (Platform::Cpu, low) => {
                // CPU low-precision GEMM still quantizes at the output.
                EmaxModel::Constant(2.5 * low.unit_roundoff())
            }
        }
    }

    /// e_max rule for an arbitrary [`AccumModel`] and verification point.
    ///
    /// `online = true` means verification reads the pre-quantization
    /// accumulator (fused-kernel ABFT): the governing precision is then the
    /// *work* precision, giving FP32-level e_max for low-precision GEMM —
    /// the paper's ~1000× granularity result.
    pub fn for_model(model: AccumModel, online: bool) -> EmaxModel {
        let governing = if online { model.work } else { model.out };
        if model.quantizes_output() && !online {
            // One dominant rounding at the output: e_max ≈ 2u_out with a
            // small margin (the NPU BF16 value 8e-3 ≈ 2.05·2^-8).
            return EmaxModel::Constant(2.05 * governing.unit_roundoff());
        }
        // Verification error accumulated in the work precision.
        let u = model.work.unit_roundoff();
        match model.strategy {
            ReduceStrategy::Pairwise => EmaxModel::Constant(6.0 * u),
            ReduceStrategy::Sequential | ReduceStrategy::Fma => EmaxModel::SqrtN {
                coeff: 1.2 * u,
                offset: 2.0 * u,
            },
        }
    }
}

/// One calibration measurement.
#[derive(Debug, Clone, Copy)]
pub struct CalibrationPoint {
    /// Matrix size (square GEMM of this side length).
    pub n: usize,
    /// max |E| / |checksum| observed.
    pub emax: f64,
    /// mean |E| / |checksum|.
    pub mean_rel: f64,
    /// Trials this point aggregates.
    pub trials: usize,
}

/// Result of a calibration sweep plus fitted scaling law.
#[derive(Debug, Clone)]
pub struct CalibrationResult {
    /// The accumulation model that was calibrated.
    pub model: AccumModel,
    /// Whether the pre-quantization accumulator was verified.
    pub online: bool,
    /// One point per calibrated size.
    pub points: Vec<CalibrationPoint>,
    /// Recommended e_max law: observed max + 20% margin, shape chosen by
    /// the √N fit quality (paper's protocol, §3.6).
    pub fitted: EmaxModel,
    /// Coefficient of variation of e_max across sizes.
    pub cv: f64,
    /// R² of the e_max ~ √N linear fit.
    pub r2_sqrt_n: f64,
}

/// The paper's one-time calibration protocol (§3.6):
/// 1. positive matrices with |N(1,1)| elements,
/// 2. relative verification error over many trials at representative sizes,
/// 3. e_max = observed max + 20% safety margin.
#[derive(Debug, Clone)]
pub struct CalibrationProtocol {
    /// Representative sizes to measure (paper: 128…2048).
    pub sizes: Vec<usize>,
    /// Trials per size (max statistic over all of them).
    pub trials_per_size: usize,
    /// Operand distribution (the paper's |N(1,1)|).
    pub distribution: Distribution,
    /// Base RNG seed; trials use deterministic (size, trial) substreams.
    pub seed: u64,
}

impl Default for CalibrationProtocol {
    fn default() -> Self {
        CalibrationProtocol {
            sizes: vec![128, 256, 512, 1024, 2048],
            trials_per_size: 20,
            distribution: Distribution::calibration(),
            seed: 0xCA11B,
        }
    }
}

impl CalibrationProtocol {
    /// Run the protocol for one accumulation model / verification point.
    pub fn run(&self, model: AccumModel, online: bool) -> CalibrationResult {
        let engine = GemmEngine::new(model);
        let mut points = Vec::new();
        for (si, &n) in self.sizes.iter().enumerate() {
            let mut emax = 0.0f64;
            let mut sum_rel = 0.0;
            for trial in 0..self.trials_per_size {
                let mut rng =
                    Xoshiro256pp::from_stream(self.seed ^ (si as u64) << 32, trial as u64);
                let rel = self.one_trial(&engine, n, online, &mut rng);
                emax = emax.max(rel);
                sum_rel += rel;
            }
            points.push(CalibrationPoint {
                n,
                emax,
                mean_rel: sum_rel / self.trials_per_size as f64,
                trials: self.trials_per_size,
            });
        }
        let (fitted, cv, r2) = fit_points(&points);
        CalibrationResult { model, online, points, fitted, cv, r2_sqrt_n: r2 }
    }

    /// One trial: max over rows of |E_i| / |checksum_i| for an n×n GEMM.
    fn one_trial(&self, engine: &GemmEngine, n: usize, online: bool, rng: &mut impl Rng) -> f64 {
        // Rectangular shrink for speed: rows beyond what's needed for a
        // max-statistic add little; use min(n, 64) rows of A.
        let m = n.min(64);
        let model = engine.model();
        let mut a = Matrix::sample(m, n, &self.distribution, rng);
        let mut b = Matrix::sample(n, n, &self.distribution, rng);
        // Keep checksums within the narrow formats' range: |N(1,1)| row
        // sums of an n×n product grow ∝ n², overflowing FP16 (max 65504)
        // beyond n ≈ 200. Scaling the operands by 1/√n leaves every
        // *relative* error — and hence e_max — unchanged.
        let scale = 1.0 / (n as f64).sqrt();
        for v in a.data_mut() {
            *v *= scale;
        }
        for v in b.data_mut() {
            *v *= scale;
        }
        a.quantize(model.input);
        b.quantize(model.input);
        // Checksum column: online keeps encodings in the datapath (work
        // precision); offline stores them like operands, on the finer of
        // the input/output grids (FP8 GEMM carries FP16 checksums — §3.6's
        // output-precision rule; see abft::encode::offline_checksum_grid).
        let grid = if online {
            model.work
        } else if model.out.mantissa_bits() > model.input.mantissa_bits() {
            model.out
        } else {
            model.input
        };
        let benc: Vec<f64> =
            (0..n).map(|k| grid.quantize(engine.reduce(b.row(k)))).collect();
        // One GEMM over [B | Br1]:
        let mut bext = Matrix::zeros(n, n + 1);
        for k in 0..n {
            bext.row_mut(k)[..n].copy_from_slice(b.row(k));
            bext.set(k, n, benc[k]);
        }
        // The checksum column is pre-quantized to `grid`; pass it as a
        // wide column so the engine doesn't coarsen it back to the input
        // grid (work-precision requantization is a no-op for it).
        let out = engine.matmul_mixed(&a, &bext, 1);
        let cmat = if online { &out.acc } else { &out.c };
        let mut worst = 0.0f64;
        for i in 0..m {
            let row = cmat.row(i);
            let checksum = row[n];
            let rowsum = engine.reduce(&row[..n]);
            let e = (checksum - rowsum).abs();
            let denom = checksum.abs().max(f64::MIN_POSITIVE);
            worst = worst.max(e / denom);
        }
        worst
    }
}

/// Fit a calibration sweep: CV, R² of e_max vs √n, and the recommended law
/// (constant when CV is small, √N law otherwise), with 20% margin.
pub fn fit_points(points: &[CalibrationPoint]) -> (EmaxModel, f64, f64) {
    let n = points.len() as f64;
    let mean_e = points.iter().map(|p| p.emax).sum::<f64>() / n;
    let var_e =
        points.iter().map(|p| (p.emax - mean_e).powi(2)).sum::<f64>() / n;
    let cv = if mean_e > 0.0 { var_e.sqrt() / mean_e } else { 0.0 };

    // Least squares: emax = a·√n + b
    let xs: Vec<f64> = points.iter().map(|p| (p.n as f64).sqrt()).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.emax).collect();
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let intercept = my - slope * mx;
    let r2 = if sxx > 0.0 && syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 0.0 };

    let fitted = if cv < 0.15 || slope <= 0.0 {
        // flat: constant = observed max + 20%
        let max_e = points.iter().fold(0.0f64, |m, p| m.max(p.emax));
        EmaxModel::Constant(max_e * 1.2)
    } else {
        EmaxModel::SqrtN { coeff: slope * 1.2, offset: intercept.max(0.0) * 1.2 }
    };
    (fitted, cv, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_values_match_paper() {
        assert_eq!(
            EmaxTable::recommended(Platform::Cpu, Precision::F64),
            EmaxModel::Constant(6e-16)
        );
        assert_eq!(
            EmaxTable::recommended(Platform::Npu, Precision::Bf16),
            EmaxModel::Constant(8e-3)
        );
        // NPU FP32 at N=1024 must give 2e-6 (Table 1).
        let m = EmaxTable::recommended(Platform::Npu, Precision::F32);
        assert!((m.eval(1024) - 2e-6).abs() < 1e-12);
        // GPU FP32 law at N=1024: 5e-9*32 + 1.2e-7 = 2.8e-7
        let g = EmaxTable::recommended(Platform::Gpu, Precision::F32);
        assert!((g.eval(1024) - 2.8e-7).abs() < 1e-12);
    }

    #[test]
    fn online_emax_is_about_1000x_finer_for_bf16() {
        // §3.6's headline: fused-kernel verification of a BF16 GEMM gets
        // FP32-level e_max (~1e-6) vs offline ~1e-3–1e-2.
        let model = AccumModel::wide(Precision::Bf16);
        let offline = EmaxTable::for_model(model, false).eval(1024);
        let online = EmaxTable::for_model(model, true).eval(1024);
        assert!(offline / online > 500.0, "offline {offline} vs online {online}");
        assert!(offline > 5e-3 && offline < 2e-2);
        assert!(online < 1e-5);
    }

    #[test]
    fn calibration_reproduces_constant_law_for_wide_models() {
        let proto = CalibrationProtocol {
            sizes: vec![64, 256, 1024],
            trials_per_size: 5,
            ..Default::default()
        };
        let res = proto.run(AccumModel::wide(Precision::Bf16), false);
        assert!(res.cv < 0.5, "wide model CV should be smallish: {}", res.cv);
        // e_max near 2u_bf16 = 7.8e-3, certainly within (0.5u, 8u)
        for p in &res.points {
            let ratio = p.emax / Precision::Bf16.unit_roundoff();
            assert!(ratio > 0.3 && ratio < 8.0, "n={} ratio={ratio}", p.n);
        }
    }

    #[test]
    fn calibration_reproduces_sqrtn_growth_for_perstep_fp32() {
        let proto = CalibrationProtocol {
            sizes: vec![64, 256, 1024, 4096],
            trials_per_size: 4,
            ..Default::default()
        };
        let res = proto.run(AccumModel::npu_fp32(), false);
        let first = res.points.first().unwrap().emax;
        let last = res.points.last().unwrap().emax;
        // 64 → 4096 is 8× in √N; demand at least 2.5× growth.
        assert!(last / first > 2.5, "expected √N growth: {first} → {last}");
    }

    #[test]
    fn calibration_cpu_model_is_flat() {
        let proto = CalibrationProtocol {
            sizes: vec![64, 256, 1024, 4096],
            trials_per_size: 4,
            ..Default::default()
        };
        let res = proto.run(AccumModel::cpu(Precision::F32), false);
        let first = res.points.first().unwrap().emax;
        let last = res.points.last().unwrap().emax;
        assert!(
            last / first < 3.0,
            "pairwise reduction should be near-flat: {first} → {last}"
        );
    }

    #[test]
    fn fit_recovers_sqrt_law() {
        let pts: Vec<CalibrationPoint> = [64usize, 256, 1024, 4096]
            .iter()
            .map(|&n| CalibrationPoint {
                n,
                emax: 3e-9 * (n as f64).sqrt() + 1e-8,
                mean_rel: 0.0,
                trials: 1,
            })
            .collect();
        let (fitted, _cv, r2) = fit_points(&pts);
        assert!(r2 > 0.999);
        match fitted {
            EmaxModel::SqrtN { coeff, .. } => {
                assert!((coeff / (3e-9 * 1.2) - 1.0).abs() < 0.05)
            }
            _ => panic!("expected sqrt law, got {fitted:?}"),
        }
    }
}
