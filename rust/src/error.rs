//! Minimal error handling (anyhow substitute — anyhow is not in the
//! offline registry).
//!
//! Provides the small surface the crate actually uses: a string-backed
//! [`Error`], a [`Result`] alias, the [`anyhow!`](crate::anyhow) and
//! [`ensure!`](crate::ensure) macros, and a [`Context`] extension trait
//! for attaching context to `Result`/`Option` values.

use std::fmt;

/// A string-backed error with an optional chain of context messages.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap a source error with a context line (most recent first).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_string() }
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Prefix the error (or turn `None` into an error) with `c`.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Like [`Context::context`], with the message built lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`](crate::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::anyhow!("base {}", 42))
    }

    #[test]
    fn display_and_context() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: base 42");
        let e2 = fails().with_context(|| format!("ctx {}", 7)).unwrap_err();
        assert_eq!(e2.to_string(), "ctx 7: base 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "too big: {x}");
            if x == 9 {
                crate::bail!("nine is right out");
            }
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(12).is_err());
        assert_eq!(check(9).unwrap_err().to_string(), "nine is right out");
    }
}
