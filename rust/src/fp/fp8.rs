//! OCP FP8 storage types: E4M3 (saturating, no Inf) and E5M2 (IEEE-like).
//!
//! §3.6 of the paper observes that FP8 GEMM on modern accelerators runs
//! FP8 inputs through an FP32 accumulator with FP16 output, so the
//! *verification* error is governed by the output precision — e_max ≈
//! 2·u_FP16 ≈ 1e-3 — not by FP8's coarse u. These types exist so the GEMM
//! engines can quantize operands to genuine FP8 grids and the experiments
//! can confirm that rule.

use super::rounding::FloatSpec;

/// FP8 E4M3: 1 sign, 4 exponent, 3 mantissa. Max finite 448, no Inf;
/// overflow saturates (H100 conversion semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct F8E4M3(pub u8);

/// FP8 E5M2: 1 sign, 5 exponent, 2 mantissa. IEEE-like with Inf/NaN.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct F8E5M2(pub u8);

macro_rules! fp8_impl {
    ($ty:ident, $spec:expr) => {
        impl $ty {
            /// The format descriptor.
            pub const SPEC: FloatSpec = $spec;

            /// Convert from f64 with round-to-nearest-even.
            pub fn from_f64(x: f64) -> $ty {
                $ty(Self::SPEC.encode(x) as u8)
            }

            /// Convert from f32 with round-to-nearest-even.
            pub fn from_f32(x: f32) -> $ty {
                Self::from_f64(x as f64)
            }

            /// Exact widening conversion.
            pub fn to_f64(self) -> f64 {
                Self::SPEC.decode(self.0 as u32)
            }

            /// Raw encoding.
            pub fn to_bits(self) -> u8 {
                self.0
            }

            /// From raw encoding.
            pub fn from_bits(bits: u8) -> $ty {
                $ty(bits)
            }

            /// Flip bit `pos` (0 = LSB .. 7 = sign) of the encoding.
            pub fn flip_bit(self, pos: u32) -> $ty {
                debug_assert!(pos < 8);
                $ty(self.0 ^ (1 << pos))
            }

            /// NaN test on the decoded value.
            pub fn is_nan(self) -> bool {
                self.to_f64().is_nan()
            }
        }

        impl std::fmt::Display for $ty {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}", self.to_f64())
            }
        }
    };
}

fp8_impl!(F8E4M3, FloatSpec::E4M3);
fp8_impl!(F8E5M2, FloatSpec::E5M2);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_value_table_spots() {
        assert_eq!(F8E4M3::from_f64(1.0).to_f64(), 1.0);
        assert_eq!(F8E4M3::from_f64(448.0).to_f64(), 448.0);
        assert_eq!(F8E4M3::from_f64(500.0).to_f64(), 448.0); // saturates
        assert_eq!(F8E4M3::from_f64(0.0625).to_f64(), 0.0625);
        // min subnormal 2^-9
        assert_eq!(F8E4M3::from_f64(0.001953125).to_f64(), 0.001953125);
    }

    #[test]
    fn e5m2_value_table_spots() {
        assert_eq!(F8E5M2::from_f64(1.0).to_f64(), 1.0);
        assert_eq!(F8E5M2::from_f64(57344.0).to_f64(), 57344.0);
        assert!(F8E5M2::from_f64(1e6).to_f64().is_infinite());
        // min subnormal 2^-16
        let ms = 2.0f64.powi(-16);
        assert_eq!(F8E5M2::from_f64(ms).to_f64(), ms);
    }

    #[test]
    fn all_encodings_roundtrip() {
        for enc in 0u8..=255 {
            let v = F8E4M3(enc).to_f64();
            if !v.is_nan() {
                assert_eq!(F8E4M3::from_f64(v).to_f64(), v);
            }
            let w = F8E5M2(enc).to_f64();
            if !w.is_nan() {
                assert_eq!(F8E5M2::from_f64(w).to_f64(), w);
            }
        }
    }

    #[test]
    fn quantization_grid_is_coarse() {
        // u = 2^-4 for E4M3: 1.0 and 1.125 are adjacent.
        assert_eq!(F8E4M3::from_f64(1.05).to_f64(), 1.0);
        assert_eq!(F8E4M3::from_f64(1.07).to_f64(), 1.125);
    }
}
