//! IEEE binary16 storage type (1 sign, 5 exponent, 10 mantissa).

use super::rounding::FloatSpec;

/// An IEEE half-precision value stored as its 16-bit encoding.
///
/// See [`super::bf16::Bf16`] for why arithmetic lives in the GEMM engines
/// rather than on the storage type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct F16(pub u16);

impl F16 {
    /// The format descriptor (5 exponent bits, 10 mantissa bits).
    pub const SPEC: FloatSpec = FloatSpec::F16;
    /// Positive zero.
    pub const ZERO: F16 = F16(0);
    /// The encoding of 1.0.
    pub const ONE: F16 = F16(0x3C00);

    /// Convert from f64 with round-to-nearest-even.
    pub fn from_f64(x: f64) -> F16 {
        F16(Self::SPEC.encode(x) as u16)
    }

    /// Convert from f32 with round-to-nearest-even.
    pub fn from_f32(x: f32) -> F16 {
        Self::from_f64(x as f64)
    }

    /// Exact widening conversion.
    pub fn to_f64(self) -> f64 {
        Self::SPEC.decode(self.0 as u32)
    }

    /// Widening conversion to f32 (exact: f16 ⊂ f32).
    pub fn to_f32(self) -> f32 {
        self.to_f64() as f32
    }

    /// Raw encoding.
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// From raw encoding.
    pub fn from_bits(bits: u16) -> F16 {
        F16(bits)
    }

    /// Flip bit `pos` (0 = LSB .. 15 = sign) of the encoding.
    pub fn flip_bit(self, pos: u32) -> F16 {
        debug_assert!(pos < 16);
        F16(self.0 ^ (1 << pos))
    }

    /// NaN test on the decoded value.
    pub fn is_nan(self) -> bool {
        self.to_f64().is_nan()
    }
}

impl std::fmt::Display for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_and_range() {
        assert_eq!(F16::ONE.to_f64(), 1.0);
        assert_eq!(F16::from_f64(65504.0).to_f64(), 65504.0);
        assert!(F16::from_f64(1e6).to_f64().is_infinite());
        // FP16 subnormal floor
        assert_eq!(F16::from_f64(6e-8).to_f64(), 5.960464477539063e-8);
    }

    #[test]
    fn exponent_layout() {
        // 1.0 = 0x3C00: exponent field at bits 10..=14.
        assert_eq!(F16::ONE.flip_bit(10).to_f64(), 0.5); // exp LSB 1→0
        assert_eq!(F16::ONE.flip_bit(15).to_f64(), -1.0); // sign
        assert_eq!(F16::ONE.flip_bit(9).to_f64(), 1.5); // mantissa MSB
    }
}
