//! bfloat16 storage type (1 sign, 8 exponent, 7 mantissa).
//!
//! BF16 shares FP32's exponent range, which is exactly why the paper's
//! Table 8 bit-flip study targets its 8 exponent bits (encoding bits 7–14):
//! a single exponent flip can scale a value by up to 2^128.

use super::rounding::FloatSpec;

/// A bfloat16 value stored as its 16-bit encoding.
///
/// Arithmetic is intentionally not implemented on the storage type: the
/// GEMM engines ([`crate::gemm`]) carry values in f64 and quantize at the
/// points dictated by the accumulation model, which is the behaviour under
/// study. `Bf16` exists to (a) hold bit-exact encodings for the fault
/// injector and (b) convert correctly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// The format descriptor (8 exponent bits, 7 mantissa bits).
    pub const SPEC: FloatSpec = FloatSpec::BF16;
    /// Positive zero.
    pub const ZERO: Bf16 = Bf16(0);
    /// The encoding of 1.0.
    pub const ONE: Bf16 = Bf16(0x3F80);

    /// Convert from f64 with round-to-nearest-even.
    pub fn from_f64(x: f64) -> Bf16 {
        Bf16(Self::SPEC.encode(x) as u16)
    }

    /// Convert from f32 with round-to-nearest-even.
    pub fn from_f32(x: f32) -> Bf16 {
        Self::from_f64(x as f64)
    }

    /// Exact widening conversion.
    pub fn to_f64(self) -> f64 {
        Self::SPEC.decode(self.0 as u32)
    }

    /// Exact widening conversion (bf16 ⊂ f32).
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// Raw encoding.
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// From raw encoding.
    pub fn from_bits(bits: u16) -> Bf16 {
        Bf16(bits)
    }

    /// Flip bit `pos` (0 = LSB .. 15 = sign) of the encoding.
    pub fn flip_bit(self, pos: u32) -> Bf16 {
        debug_assert!(pos < 16);
        Bf16(self.0 ^ (1 << pos))
    }

    /// NaN test on the decoded value.
    pub fn is_nan(self) -> bool {
        self.to_f64().is_nan()
    }

    /// Infinity test on the decoded value.
    pub fn is_infinite(self) -> bool {
        self.to_f64().is_infinite()
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> Self {
        x.to_f32()
    }
}

impl std::fmt::Display for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_f32_matches_decode() {
        for enc in (0u16..=0xFFFF).step_by(7) {
            let b = Bf16(enc);
            let via_f32 = b.to_f32() as f64;
            let via_spec = b.to_f64();
            if via_f32.is_nan() {
                assert!(via_spec.is_nan());
            } else {
                assert_eq!(via_f32, via_spec, "enc={enc:#x}");
            }
        }
    }

    #[test]
    fn one_constant() {
        assert_eq!(Bf16::ONE.to_f64(), 1.0);
        assert_eq!(Bf16::from_f64(1.0), Bf16::ONE);
    }

    #[test]
    fn exponent_flip_magnitude() {
        // Flipping exponent bit k multiplies the value by 2^(2^(k-7)) (for
        // a 0→1 flip) — the catastrophic-amplification property from §2.1.
        let one = Bf16::from_f64(1.0); // exponent field 127 = 0b01111111
        // bit 14 (exponent MSB) is 0 for 1.0; flipping gives exp 255 → inf/nan range
        let flipped = one.flip_bit(14);
        assert!(flipped.to_f64().is_infinite() || flipped.to_f64().is_nan());
        // bit 7 (exponent LSB) is 1 for 1.0; flipping gives exp 126 → 0.5
        assert_eq!(one.flip_bit(7).to_f64(), 0.5);
        // sign bit
        assert_eq!(one.flip_bit(15).to_f64(), -1.0);
        // mantissa MSB: 1.0 → 1.5
        assert_eq!(one.flip_bit(6).to_f64(), 1.5);
    }
}
