//! Generic binary floating-point encode/decode with round-to-nearest-even.
//!
//! All narrow formats in this crate (BF16, FP16, FP8 E4M3/E5M2) are defined
//! by a [`FloatSpec`] and share one correctly-rounded conversion path from
//! f64. Handles normals, subnormals, signed zero, Inf/NaN, saturating
//! formats without an infinity (E4M3), and rounding overflow into the next
//! exponent or into Inf.

/// 2^e as f64, assembled from bits. `e` must be a *normal* f64 exponent
/// (−1022 ..= 1023), which holds for every derived constant of a ≤ 32-bit
/// format. Replaces the `powi` calls that used to sit on the quantization
/// hot path.
#[inline]
fn pow2(e: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&e));
    f64::from_bits(((e + 1023) as u64) << 52)
}

/// Static description of a binary floating-point format (≤ 32 bits wide).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FloatSpec {
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Stored mantissa (fraction) width in bits.
    pub man_bits: u32,
    /// Whether the all-ones exponent encodes Inf/NaN (IEEE style). When
    /// false (FP8 E4M3), the all-ones exponent holds normal numbers except
    /// the all-ones mantissa, which is NaN; overflow saturates to the
    /// largest finite value (matching H100 saturating conversions).
    pub has_inf: bool,
}

impl FloatSpec {
    /// bfloat16: 8 exponent bits, 7 mantissa bits, IEEE Inf/NaN.
    pub const BF16: FloatSpec = FloatSpec { exp_bits: 8, man_bits: 7, has_inf: true };
    /// IEEE binary16: 5 exponent bits, 10 mantissa bits.
    pub const F16: FloatSpec = FloatSpec { exp_bits: 5, man_bits: 10, has_inf: true };
    /// OCP FP8 E4M3: saturating, no Inf (overflow → max finite).
    pub const E4M3: FloatSpec = FloatSpec { exp_bits: 4, man_bits: 3, has_inf: false };
    /// OCP FP8 E5M2: IEEE-like with Inf/NaN.
    pub const E5M2: FloatSpec = FloatSpec { exp_bits: 5, man_bits: 2, has_inf: true };

    /// Exponent bias.
    pub const fn bias(self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Total width in bits (sign + exponent + mantissa).
    pub const fn bits(self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Largest finite value of the format.
    ///
    /// Assembled directly as f64 bits (every exponent of a ≤ 32-bit format
    /// is a normal f64 exponent), so this is a handful of integer ops — no
    /// `powi` — and cheap enough for the `encode` hot path to call.
    #[inline]
    pub fn max_finite(self) -> f64 {
        let (e_top, man) = if self.has_inf {
            // exp field 2^eb - 2, mantissa all ones: (2 - 2^-m) * 2^bias
            (self.bias(), ((1u64 << self.man_bits) - 1) << (52 - self.man_bits))
        } else {
            // E4M3: exp field all ones, mantissa 111...0 (all-ones is NaN):
            // (2 - 2^-(m-1)) * 2^e_max
            let e_max = ((1 << self.exp_bits) - 1) - self.bias();
            (e_max, ((1u64 << self.man_bits) - 2) << (52 - self.man_bits))
        };
        f64::from_bits((((e_top + 1023) as u64) << 52) | man)
    }

    /// Smallest positive normal value, 2^(1 - bias) (bit-assembled, no
    /// `powi`).
    #[inline]
    pub fn min_normal(self) -> f64 {
        pow2(1 - self.bias())
    }

    /// Smallest positive subnormal value, 2^(1 - bias - man_bits)
    /// (bit-assembled, no `powi`).
    #[inline]
    pub fn min_subnormal(self) -> f64 {
        pow2(1 - self.bias() - self.man_bits as i32)
    }

    /// Encoding of the canonical quiet NaN.
    pub fn nan_bits(self) -> u32 {
        if self.has_inf {
            // exponent all ones, MSB of mantissa set
            let exp_all = ((1u32 << self.exp_bits) - 1) << self.man_bits;
            exp_all | (1 << (self.man_bits - 1))
        } else {
            // E4M3: S.1111.111
            (1u32 << (self.exp_bits + self.man_bits)) - 1
        }
    }

    /// Encoding of +Inf (only meaningful when `has_inf`).
    pub fn inf_bits(self) -> u32 {
        ((1u32 << self.exp_bits) - 1) << self.man_bits
    }

    /// Encode an f64 into this format with round-to-nearest-even.
    #[inline]
    pub fn encode(self, x: f64) -> u32 {
        let bits64 = x.to_bits();
        let sign = ((bits64 >> 63) & 1) as u32;
        let sign_enc = sign << (self.exp_bits + self.man_bits);

        if x.is_nan() {
            return sign_enc | self.nan_bits();
        }
        if x.is_infinite() {
            return if self.has_inf {
                sign_enc | self.inf_bits()
            } else {
                // Saturating format: ±Inf maps to NaN per OCP FP8 spec.
                sign_enc | self.nan_bits()
            };
        }
        if x == 0.0 {
            return sign_enc; // preserves signed zero
        }

        // Decompose |x| into sig * 2^(e - 52) with sig in [2^52, 2^53).
        let mut e = ((bits64 >> 52) & 0x7FF) as i32 - 1023;
        let mut sig = bits64 & ((1u64 << 52) - 1);
        if ((bits64 >> 52) & 0x7FF) == 0 {
            // f64 subnormal: normalize.
            let shift = sig.leading_zeros() - 11; // bring MSB to bit 52
            sig <<= shift;
            e = -1022 - shift as i32;
        } else {
            sig |= 1u64 << 52;
        }

        let bias = self.bias();
        let e_min = 1 - bias; // smallest normal exponent
        let e_max = if self.has_inf {
            bias
        } else {
            ((1 << self.exp_bits) - 1) - bias
        };

        // Total right shift from the 53-bit significand to the target.
        let base_shift = 52 - self.man_bits;
        let extra = if e < e_min { (e_min - e) as u32 } else { 0 };
        let shift = base_shift + extra;

        let (mut t_sig, rounded_up);
        if shift >= 63 {
            // Value far below the subnormal range: rounds to zero unless it
            // is at least half the smallest subnormal.
            let half_min_sub = self.min_subnormal() / 2.0;
            let ax = x.abs();
            t_sig = if ax > half_min_sub { 1 } else { 0 }; // exactly half → even (0)
            rounded_up = false;
            let _ = rounded_up;
            return sign_enc | t_sig as u32;
        } else {
            let mask = (1u64 << shift) - 1;
            let rem = sig & mask;
            t_sig = sig >> shift;
            let half = 1u64 << (shift - 1);
            if rem > half || (rem == half && (t_sig & 1) == 1) {
                t_sig += 1;
                rounded_up = true;
            } else {
                rounded_up = false;
            }
            let _ = rounded_up;
        }

        let mut e_out = if extra > 0 { e_min } else { e };
        // Rounding may carry into the next binade (or promote a subnormal
        // to the smallest normal, which the encoding handles for free).
        if t_sig >= (1u64 << (self.man_bits + 1)) {
            t_sig >>= 1;
            e_out += 1;
        }

        if extra > 0 && t_sig < (1u64 << self.man_bits) {
            // Subnormal result: exponent field 0, no implicit bit.
            return sign_enc | t_sig as u32;
        }

        if e_out > e_max {
            return if self.has_inf {
                sign_enc | self.inf_bits()
            } else {
                // Saturate (H100-style FP8 conversion).
                self.encode(if sign == 1 { -self.max_finite() } else { self.max_finite() })
            };
        }
        if !self.has_inf && e_out == e_max {
            // E4M3: top binade loses its top mantissa code to NaN.
            let man = (t_sig as u32) & ((1 << self.man_bits) - 1);
            if man == (1 << self.man_bits) - 1 {
                // would collide with NaN — saturate to max finite
                let exp_field = ((e_out + bias) as u32) << self.man_bits;
                return sign_enc | exp_field | (((1 << self.man_bits) - 1) - 1);
            }
        }

        let exp_field = ((e_out + bias) as u32) << self.man_bits;
        let man_field = (t_sig as u32) & ((1 << self.man_bits) - 1);
        sign_enc | exp_field | man_field
    }

    /// Decode an encoding of this format to f64 (exact).
    ///
    /// Hot path (it runs once per element per quantization): the result is
    /// assembled directly as f64 bits — every value of a ≤ 32-bit format is
    /// exactly representable in f64, so no rounding and no `powi` calls.
    #[inline]
    pub fn decode(self, enc: u32) -> f64 {
        let man_mask = (1u32 << self.man_bits) - 1;
        let exp_mask = (1u32 << self.exp_bits) - 1;
        let sign = (enc >> (self.exp_bits + self.man_bits)) & 1;
        let exp_field = (enc >> self.man_bits) & exp_mask;
        let man = enc & man_mask;
        let sign_bits = (sign as u64) << 63;
        let bias = self.bias();

        if exp_field == exp_mask {
            if self.has_inf {
                return if man == 0 {
                    f64::from_bits(sign_bits | 0x7FF0_0000_0000_0000)
                } else {
                    f64::NAN
                };
            } else if man == man_mask {
                return f64::NAN; // E4M3 NaN
            }
            // else: fall through, E4M3 normal in the top binade
        }
        if exp_field == 0 {
            // Subnormal (or zero): man · 2^(1 − bias − man_bits), built as
            // an exact product of two f64s (both exact integers/powers).
            if man == 0 {
                return f64::from_bits(sign_bits);
            }
            let k = 1 - bias - self.man_bits as i32;
            let scale = f64::from_bits(((1023 + k) as u64) << 52);
            let v = man as f64 * scale;
            return if sign == 1 { -v } else { v };
        }
        // Normal: widen exponent to f64 bias, shift mantissa into place.
        let e64 = (exp_field as i64 - bias as i64 + 1023) as u64;
        let m64 = (man as u64) << (52 - self.man_bits);
        f64::from_bits(sign_bits | (e64 << 52) | m64)
    }

    /// Round an f64 to the nearest representable value of this format.
    #[inline]
    pub fn quantize(self, x: f64) -> f64 {
        self.decode(self.encode(x))
    }

    /// Quantize a slice in place — the batched form the blocked GEMM paths
    /// use. One `self` copy is resolved before the loop, so per-`FloatSpec`
    /// constants (bias, shifts, subnormal floor) are hoisted by inlining
    /// instead of being re-derived per element; element-wise results are
    /// identical to [`FloatSpec::quantize`] by construction.
    #[inline]
    pub fn quantize_slice(self, xs: &mut [f64]) {
        for x in xs.iter_mut() {
            *x = self.decode(self.encode(*x));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_known_values() {
        let s = FloatSpec::BF16;
        assert_eq!(s.quantize(1.0), 1.0);
        assert_eq!(s.quantize(-2.0), -2.0);
        // 1 + 2^-8 is exactly halfway between 1.0 and 1 + 2^-7 → even (1.0)
        assert_eq!(s.quantize(1.0 + 2.0f64.powi(-8)), 1.0);
        // just above halfway rounds up
        assert_eq!(s.quantize(1.0 + 2.0f64.powi(-8) + 1e-6), 1.0 + 2.0f64.powi(-7));
        // bf16 of pi = 3.140625
        assert_eq!(s.quantize(std::f64::consts::PI), 3.140625);
        assert_eq!(s.max_finite(), 3.3895313892515355e38);
        assert!(s.quantize(1e39).is_infinite());
    }

    #[test]
    fn bf16_matches_f32_truncation_semantics() {
        // BF16 quantization must equal rounding the f32 to 8 mantissa bits.
        // Cross-check against an independent path: f32 bits + RNE by hand.
        let s = FloatSpec::BF16;
        let mut state = 12345u64;
        for _ in 0..10_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let f = f32::from_bits((state >> 32) as u32);
            if !f.is_finite() {
                continue;
            }
            let got = s.quantize(f as f64);
            // reference: round f32 to bf16 via integer arithmetic
            let b = f.to_bits();
            let lsb = (b >> 16) & 1;
            let rounded = b.wrapping_add(0x7FFF + lsb);
            let ref_bits = (rounded >> 16) as u16;
            let ref_val = f32::from_bits((ref_bits as u32) << 16) as f64;
            if ref_val.is_nan() || got.is_nan() {
                continue; // overflow-to-inf edge differences are tested above
            }
            assert_eq!(got, ref_val, "mismatch at {f}");
        }
    }

    #[test]
    fn f16_known_values() {
        let s = FloatSpec::F16;
        assert_eq!(s.quantize(1.0), 1.0);
        assert_eq!(s.max_finite(), 65504.0);
        assert_eq!(s.min_normal(), 6.103515625e-5);
        assert_eq!(s.min_subnormal(), 5.960464477539063e-8);
        assert!(s.quantize(65520.0).is_infinite()); // above halfway to 65536
        assert_eq!(s.quantize(65519.0), 65504.0);
        // subnormal rounding
        assert_eq!(s.quantize(s.min_subnormal() * 1.4), s.min_subnormal());
        assert_eq!(s.quantize(s.min_subnormal() * 0.6), s.min_subnormal());
        assert_eq!(s.quantize(s.min_subnormal() * 0.4), 0.0);
        // exactly half the min subnormal ties to even → 0
        assert_eq!(s.quantize(s.min_subnormal() * 0.5), 0.0);
    }

    #[test]
    fn e4m3_saturation_and_nan() {
        let s = FloatSpec::E4M3;
        assert_eq!(s.max_finite(), 448.0);
        assert_eq!(s.quantize(448.0), 448.0);
        assert_eq!(s.quantize(1e9), 448.0); // saturates, no inf
        assert_eq!(s.quantize(-1e9), -448.0);
        assert!(s.quantize(f64::NAN).is_nan());
        assert!(s.decode(0x7F).is_nan());
        assert!(s.decode(0xFF).is_nan());
        // 464 is closer to 448 than to the (nonexistent) 480 → but also in
        // the saturating regime either way.
        assert_eq!(s.quantize(464.0), 448.0);
        assert_eq!(s.min_subnormal(), 2.0f64.powi(-9));
    }

    #[test]
    fn e5m2_is_ieee_like() {
        let s = FloatSpec::E5M2;
        assert_eq!(s.max_finite(), 57344.0);
        assert!(s.quantize(1e9).is_infinite());
        assert_eq!(s.quantize(1.0), 1.0);
        assert_eq!(s.quantize(1.26), 1.25);
    }

    #[test]
    fn signed_zero_preserved() {
        for s in [FloatSpec::BF16, FloatSpec::F16, FloatSpec::E4M3, FloatSpec::E5M2] {
            assert_eq!(s.quantize(0.0).to_bits(), 0.0f64.to_bits());
            assert_eq!(s.quantize(-0.0).to_bits(), (-0.0f64).to_bits());
        }
    }

    #[test]
    fn quantize_idempotent_exhaustive_fp8() {
        // FP8 formats are small enough to test every encoding.
        for s in [FloatSpec::E4M3, FloatSpec::E5M2] {
            for enc in 0u32..=0xFF {
                let v = s.decode(enc);
                if v.is_nan() {
                    assert!(s.decode(s.encode(v)).is_nan());
                } else {
                    assert_eq!(
                        s.decode(s.encode(v)),
                        v,
                        "roundtrip failed for enc {enc:#x} -> {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_exhaustive_f16_roundtrip() {
        let s = FloatSpec::F16;
        for enc in 0u32..=0xFFFF {
            let v = s.decode(enc);
            if v.is_nan() {
                continue;
            }
            let back = s.encode(v);
            assert_eq!(s.decode(back), v, "enc {enc:#x}");
        }
    }

    #[test]
    fn bit_assembled_constants_match_powi_formulas() {
        // The pow2 bit assembly must reproduce the old powi-based math
        // exactly for every spec (these are load-bearing constants: the
        // encode subnormal-flush and saturation branches read them).
        for s in [FloatSpec::BF16, FloatSpec::F16, FloatSpec::E4M3, FloatSpec::E5M2] {
            let bias = s.bias();
            let want_max = if s.has_inf {
                (2.0 - (2.0f64).powi(-(s.man_bits as i32))) * (2.0f64).powi(bias)
            } else {
                let e_max = ((1 << s.exp_bits) - 1) - bias;
                (2.0 - (2.0f64).powi(-(s.man_bits as i32 - 1))) * (2.0f64).powi(e_max)
            };
            assert_eq!(s.max_finite(), want_max, "max_finite {s:?}");
            assert_eq!(s.min_normal(), (2.0f64).powi(1 - bias), "min_normal {s:?}");
            assert_eq!(
                s.min_subnormal(),
                (2.0f64).powi(1 - bias - s.man_bits as i32),
                "min_subnormal {s:?}"
            );
        }
    }

    #[test]
    fn quantize_slice_matches_scalar_quantize() {
        let mut state = 0xD1CEu64;
        for s in [FloatSpec::BF16, FloatSpec::F16, FloatSpec::E4M3, FloatSpec::E5M2] {
            let mut xs: Vec<f64> = (0..512)
                .map(|i| {
                    state = state
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    let u = (state >> 11) as f64 / (1u64 << 53) as f64;
                    // Mix normal-range, subnormal-range and huge values.
                    match i % 3 {
                        0 => (u - 0.5) * 8.0,
                        1 => (u - 0.5) * s.min_normal(),
                        _ => (u - 0.5) * 1e40,
                    }
                })
                .collect();
            let want: Vec<f64> = xs.iter().map(|&x| s.quantize(x)).collect();
            s.quantize_slice(&mut xs);
            for (got, want) in xs.iter().zip(&want) {
                assert_eq!(got.to_bits(), want.to_bits(), "{s:?}");
            }
        }
    }

    #[test]
    fn monotonic_rounding() {
        // Quantization must be monotone non-decreasing.
        let s = FloatSpec::E4M3;
        let mut prev = f64::NEG_INFINITY;
        let mut x = -500.0;
        while x < 500.0 {
            let q = s.quantize(x);
            assert!(q >= prev, "non-monotone at {x}: {q} < {prev}");
            prev = q;
            x += 0.0437;
        }
    }
}
