//! Software floating-point substrate.
//!
//! Mixed-precision GEMM simulation requires *controllable* rounding: the
//! paper's e_max analysis (§3.6) hinges on exactly where rounding happens
//! (per accumulation step vs. once at output) and in which format. The
//! `half` crate is not available offline, and it would not give us FP8 or
//! a high-precision baseline anyway, so this module implements:
//!
//! * [`Precision`] — format descriptors (BF16, FP16, FP8 E4M3/E5M2, FP32,
//!   FP64) with unit roundoff, bit layout and quantization.
//! * [`bf16::Bf16`], [`f16::F16`], [`fp8::F8E4M3`], [`fp8::F8E5M2`] —
//!   bit-exact storage types used by the fault injector (bit flips operate
//!   on the stored encodings).
//! * [`dd::Dd`] — double-double (~106-bit significand) arithmetic, the
//!   stand-in for the paper's mpmath 100-decimal-place baseline.
//!
//! All conversions use round-to-nearest-even with subnormal and Inf/NaN
//! handling, matching IEEE 754 semantics for the custom widths.

pub mod bf16;
pub mod dd;
pub mod f16;
pub mod fp8;
pub mod rounding;

pub use bf16::Bf16;
pub use f16::F16;
pub use fp8::{F8E4M3, F8E5M2};

/// Floating-point format descriptor.
///
/// `unit_roundoff` follows the paper's convention (u = 2^-(t+1) with t
/// stored mantissa bits is the *round-to-nearest* unit roundoff; the paper
/// quotes u = 2^-8 for BF16 and u = 2^-24 for FP32, i.e. 2^-(mant_bits+1)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// bfloat16: 1 sign, 8 exponent, 7 mantissa. u = 2^-8.
    Bf16,
    /// IEEE binary16: 1 sign, 5 exponent, 10 mantissa. u = 2^-11.
    F16,
    /// FP8 E4M3 (OCP): 1 sign, 4 exponent, 3 mantissa. u = 2^-4.
    F8E4M3,
    /// FP8 E5M2 (OCP): 1 sign, 5 exponent, 2 mantissa. u = 2^-3.
    F8E5M2,
    /// IEEE binary32. u = 2^-24.
    F32,
    /// IEEE binary64. u = 2^-53.
    F64,
}

impl Precision {
    /// All formats, low → high precision.
    pub const ALL: [Precision; 6] = [
        Precision::F8E5M2,
        Precision::F8E4M3,
        Precision::Bf16,
        Precision::F16,
        Precision::F32,
        Precision::F64,
    ];

    /// Number of stored mantissa (fraction) bits.
    pub fn mantissa_bits(self) -> u32 {
        match self {
            Precision::Bf16 => 7,
            Precision::F16 => 10,
            Precision::F8E4M3 => 3,
            Precision::F8E5M2 => 2,
            Precision::F32 => 23,
            Precision::F64 => 52,
        }
    }

    /// Number of exponent bits.
    pub fn exponent_bits(self) -> u32 {
        match self {
            Precision::Bf16 => 8,
            Precision::F16 => 5,
            Precision::F8E4M3 => 4,
            Precision::F8E5M2 => 5,
            Precision::F32 => 8,
            Precision::F64 => 11,
        }
    }

    /// Total storage width in bits.
    pub fn bits(self) -> u32 {
        1 + self.exponent_bits() + self.mantissa_bits()
    }

    /// Unit roundoff u = 2^-(mant_bits + 1) (round-to-nearest).
    pub fn unit_roundoff(self) -> f64 {
        (2.0f64).powi(-(self.mantissa_bits() as i32 + 1))
    }

    /// Exponent bias (2^(e-1) - 1).
    pub fn bias(self) -> i32 {
        (1 << (self.exponent_bits() - 1)) - 1
    }

    /// Quantize an f64 to this format (round-to-nearest-even), returning
    /// the nearest representable value as f64. This is the primitive that
    /// the accumulation models in [`crate::gemm`] are built on.
    ///
    /// BF16 uses a fast two-step path (f64→f32 in hardware, then an
    /// integer round of the low 16 bits). The composition can differ from
    /// a single direct rounding only when the f32 step lands exactly on a
    /// BF16 tie point (relative deviation < 2⁻²⁴, i.e. one BF16 ulp choice
    /// on a ~2⁻¹⁶ fraction of inputs) — immaterial for every experiment,
    /// and idempotence/monotonicity are preserved. Bit-level consumers
    /// (the fault injector) use [`Bf16::from_f64`], which stays exact.
    #[inline]
    pub fn quantize(self, x: f64) -> f64 {
        match self {
            Precision::F64 => x,
            Precision::F32 => x as f32 as f64,
            Precision::Bf16 => quantize_bf16(x),
            Precision::F16 => F16::from_f64(x).to_f64(),
            Precision::F8E4M3 => F8E4M3::from_f64(x).to_f64(),
            Precision::F8E5M2 => F8E5M2::from_f64(x).to_f64(),
        }
    }

    /// Quantize a slice in place — the batched form of
    /// [`Precision::quantize`], bitwise-identical element-wise.
    ///
    /// The format dispatch happens once per slice instead of once per
    /// element, and the per-format inner loops are tight enough for the
    /// compiler to vectorize (BF16/F32) or at least keep the
    /// [`rounding::FloatSpec`] constants in registers (F16/FP8). This is
    /// the primitive the blocked generic GEMM path
    /// ([`crate::gemm::tiled::gemm_generic`]) and the ABFT aggregation
    /// loop are built on; `benches/microkernel.rs` measures the win over
    /// a per-element `quantize` loop.
    #[inline]
    pub fn quantize_slice(self, xs: &mut [f64]) {
        match self {
            Precision::F64 => {}
            Precision::F32 => {
                for x in xs.iter_mut() {
                    *x = *x as f32 as f64;
                }
            }
            Precision::Bf16 => {
                for x in xs.iter_mut() {
                    *x = quantize_bf16(*x);
                }
            }
            Precision::F16 => rounding::FloatSpec::F16.quantize_slice(xs),
            Precision::F8E4M3 => rounding::FloatSpec::E4M3.quantize_slice(xs),
            Precision::F8E5M2 => rounding::FloatSpec::E5M2.quantize_slice(xs),
        }
    }

    /// Short lowercase name used in CLIs, artifact names and reports.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Bf16 => "bf16",
            Precision::F16 => "fp16",
            Precision::F8E4M3 => "fp8e4m3",
            Precision::F8E5M2 => "fp8e5m2",
            Precision::F32 => "fp32",
            Precision::F64 => "fp64",
        }
    }

    /// Parse a precision name as accepted by [`Precision::name`] plus a
    /// few aliases (`f32`, `float32`, ...).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" | "bfloat16" => Some(Precision::Bf16),
            "fp16" | "f16" | "float16" | "half" => Some(Precision::F16),
            "fp8" | "fp8e4m3" | "e4m3" => Some(Precision::F8E4M3),
            "fp8e5m2" | "e5m2" => Some(Precision::F8E5M2),
            "fp32" | "f32" | "float32" | "single" => Some(Precision::F32),
            "fp64" | "f64" | "float64" | "double" => Some(Precision::F64),
            _ => None,
        }
    }

    /// Index of the least-significant exponent bit in the storage encoding
    /// (bit positions count from 0 = LSB of the encoding). For BF16 this is
    /// 7, matching the paper's "bits 7–14" exponent range in Table 8.
    pub fn exponent_lsb(self) -> u32 {
        self.mantissa_bits()
    }

    /// Index of the sign bit in the storage encoding.
    pub fn sign_bit(self) -> u32 {
        self.bits() - 1
    }
}

/// The BF16 fast path shared by [`Precision::quantize`] and
/// [`Precision::quantize_slice`]: f64→f32 in hardware, then an integer
/// round-to-nearest-even of the low 16 bits (see the `quantize` docs for
/// the tie-point caveat).
#[inline]
fn quantize_bf16(x: f64) -> f64 {
    let f = x as f32;
    if !f.is_finite() {
        return f as f64; // Inf/NaN pass through
    }
    let b = f.to_bits();
    let rounded = b.wrapping_add(0x7FFF + ((b >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000) as f64
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_roundoff_matches_paper() {
        // Paper §1: u = 2^-8 for BF16, u = 2^-24 for FP32.
        assert_eq!(Precision::Bf16.unit_roundoff(), 2.0f64.powi(-8));
        assert_eq!(Precision::F32.unit_roundoff(), 2.0f64.powi(-24));
        // Table 1: FP16 u = 2^-11.
        assert_eq!(Precision::F16.unit_roundoff(), 2.0f64.powi(-11));
        assert_eq!(Precision::F64.unit_roundoff(), 2.0f64.powi(-53));
    }

    #[test]
    fn bit_layout() {
        assert_eq!(Precision::Bf16.bits(), 16);
        assert_eq!(Precision::F16.bits(), 16);
        assert_eq!(Precision::F8E4M3.bits(), 8);
        assert_eq!(Precision::F8E5M2.bits(), 8);
        // BF16 exponent occupies bits 7..=14, sign bit 15 (Table 8's
        // "bits 7-15" injection range).
        assert_eq!(Precision::Bf16.exponent_lsb(), 7);
        assert_eq!(Precision::Bf16.sign_bit(), 15);
        assert_eq!(Precision::Bf16.bias(), 127);
        assert_eq!(Precision::F16.bias(), 15);
        assert_eq!(Precision::F8E4M3.bias(), 7);
    }

    #[test]
    fn quantize_f32_roundtrip() {
        for &x in &[0.0, 1.0, -1.5, 3.14159, 1e-30, -2.5e20] {
            assert_eq!(Precision::F32.quantize(x), x as f32 as f64);
        }
    }

    #[test]
    fn quantize_is_idempotent() {
        let mut state = 0x9E3779B97F4A7C15u64;
        for p in Precision::ALL {
            for _ in 0..200 {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                let x = ((state >> 11) as f64 / (1u64 << 53) as f64 - 0.5) * 8.0;
                let q = p.quantize(x);
                assert_eq!(p.quantize(q), q, "{p:?} not idempotent at {x}");
            }
        }
    }

    #[test]
    fn quantize_slice_is_bitwise_equal_to_quantize() {
        let mut state = 0xABCDu64;
        for p in Precision::ALL {
            let mut xs: Vec<f64> = (0..300)
                .map(|i| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    let u = (state >> 11) as f64 / (1u64 << 53) as f64 - 0.5;
                    match i % 3 {
                        0 => u * 8.0,
                        1 => u * 1e-6, // subnormal range for the narrow formats
                        _ => u * 1e6,
                    }
                })
                .collect();
            let want: Vec<f64> = xs.iter().map(|&x| p.quantize(x)).collect();
            p.quantize_slice(&mut xs);
            for (got, want) in xs.iter().zip(&want) {
                assert_eq!(got.to_bits(), want.to_bits(), "{p:?}");
            }
        }
    }

    #[test]
    fn parse_names() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("float32"), Some(Precision::F32));
        assert_eq!(Precision::parse("nonsense"), None);
    }
}
