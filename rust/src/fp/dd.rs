//! Double-double arithmetic: unevaluated sums `hi + lo` of two f64s giving
//! ~106 significand bits (~32 decimal digits).
//!
//! This is the repository's substitute for the paper's mpmath
//! 100-decimal-place baseline (§6.2, Table 4): the *true* FP64
//! verification difference is ~1e-13–1e-12 for the tested sizes, while
//! double-double keeps relative error ~1e-32 per operation — more than ten
//! orders of magnitude below the quantity being measured, so the
//! substitution cannot perturb the reported tightness ratios.
//!
//! Algorithms are the classical error-free transformations (Dekker 1971,
//! Knuth TAOCP v2) with `two_prod` built on the hardware FMA via
//! [`f64::mul_add`].

/// A double-double number: the unevaluated sum `hi + lo`, |lo| ≤ ulp(hi)/2.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Dd {
    /// Leading component (the f64 nearest the represented value).
    pub hi: f64,
    /// Trailing error component.
    pub lo: f64,
}

/// Error-free sum: a + b = s + e exactly, s = fl(a + b).
#[inline]
fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let bb = s - a;
    let e = (a - (s - bb)) + (b - bb);
    (s, e)
}

/// Error-free sum assuming |a| ≥ |b|.
#[inline]
fn quick_two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let e = b - (s - a);
    (s, e)
}

/// Error-free product via FMA: a·b = p + e exactly.
#[inline]
fn two_prod(a: f64, b: f64) -> (f64, f64) {
    let p = a * b;
    let e = a.mul_add(b, -p);
    (p, e)
}

impl Dd {
    /// Additive identity.
    pub const ZERO: Dd = Dd { hi: 0.0, lo: 0.0 };
    /// Multiplicative identity.
    pub const ONE: Dd = Dd { hi: 1.0, lo: 0.0 };

    /// Lift an f64 exactly.
    #[inline]
    pub fn from_f64(x: f64) -> Dd {
        Dd { hi: x, lo: 0.0 }
    }

    /// Round to nearest f64.
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.hi + self.lo
    }

    /// Exact product of two f64s (error-free).
    #[inline]
    pub fn prod(a: f64, b: f64) -> Dd {
        let (p, e) = two_prod(a, b);
        Dd { hi: p, lo: e }
    }

    /// dd + dd (Dekker add, ~106-bit accurate).
    #[inline]
    pub fn add(self, other: Dd) -> Dd {
        let (s1, s2) = two_sum(self.hi, other.hi);
        let (t1, t2) = two_sum(self.lo, other.lo);
        let s2 = s2 + t1;
        let (s1, s2) = quick_two_sum(s1, s2);
        let s2 = s2 + t2;
        let (hi, lo) = quick_two_sum(s1, s2);
        Dd { hi, lo }
    }

    /// dd + f64.
    #[inline]
    pub fn add_f64(self, x: f64) -> Dd {
        let (s1, s2) = two_sum(self.hi, x);
        let s2 = s2 + self.lo;
        let (hi, lo) = quick_two_sum(s1, s2);
        Dd { hi, lo }
    }

    /// dd − dd.
    #[inline]
    pub fn sub(self, other: Dd) -> Dd {
        self.add(other.neg())
    }

    /// Negation (exact).
    #[inline]
    pub fn neg(self) -> Dd {
        Dd { hi: -self.hi, lo: -self.lo }
    }

    /// dd × dd.
    #[inline]
    pub fn mul(self, other: Dd) -> Dd {
        let (p1, p2) = two_prod(self.hi, other.hi);
        let p2 = p2 + self.hi * other.lo + self.lo * other.hi;
        let (hi, lo) = quick_two_sum(p1, p2);
        Dd { hi, lo }
    }

    /// dd × f64.
    #[inline]
    pub fn mul_f64(self, x: f64) -> Dd {
        let (p1, p2) = two_prod(self.hi, x);
        let p2 = p2 + self.lo * x;
        let (hi, lo) = quick_two_sum(p1, p2);
        Dd { hi, lo }
    }

    /// Fused accumulate: self + a·b with the product kept error-free.
    #[inline]
    pub fn mul_acc(self, a: f64, b: f64) -> Dd {
        self.add(Dd::prod(a, b))
    }

    /// dd / dd (one Newton step past the f64 quotient; ~106-bit).
    pub fn div(self, other: Dd) -> Dd {
        let q1 = self.hi / other.hi;
        let r = self.sub(other.mul_f64(q1));
        let q2 = r.hi / other.hi;
        let r2 = r.sub(other.mul_f64(q2));
        let q3 = r2.hi / other.hi;
        let (hi, lo) = quick_two_sum(q1, q2);
        Dd { hi, lo }.add_f64(q3)
    }

    /// Absolute value.
    pub fn abs(self) -> Dd {
        if self.hi < 0.0 || (self.hi == 0.0 && self.lo < 0.0) {
            self.neg()
        } else {
            self
        }
    }

    /// Exact dot product of two f64 slices, accumulated in double-double.
    pub fn dot(a: &[f64], b: &[f64]) -> Dd {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = Dd::ZERO;
        for (&x, &y) in a.iter().zip(b.iter()) {
            acc = acc.mul_acc(x, y);
        }
        acc
    }

    /// Sum of a f64 slice in double-double.
    pub fn sum(xs: &[f64]) -> Dd {
        let mut acc = Dd::ZERO;
        for &x in xs {
            acc = acc.add_f64(x);
        }
        acc
    }
}

impl std::ops::Add for Dd {
    type Output = Dd;
    fn add(self, rhs: Dd) -> Dd {
        Dd::add(self, rhs)
    }
}

impl std::ops::Sub for Dd {
    type Output = Dd;
    fn sub(self, rhs: Dd) -> Dd {
        Dd::sub(self, rhs)
    }
}

impl std::ops::Mul for Dd {
    type Output = Dd;
    fn mul(self, rhs: Dd) -> Dd {
        Dd::mul(self, rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_sum_is_error_free() {
        let (s, e) = two_sum(1e16, 1.0);
        assert_eq!(s, 1e16); // 1.0 lost in f64...
        assert_eq!(e, 1.0); // ...but recovered exactly in the error term
    }

    #[test]
    fn two_prod_is_error_free() {
        let a = 1.0 + 2f64.powi(-30);
        let b = 1.0 + 2f64.powi(-30);
        let (p, e) = two_prod(a, b);
        // a*b = 1 + 2^-29 + 2^-60; the 2^-60 term is below f64 resolution
        // of p but captured by e.
        assert_eq!(p + e, a * b); // consistency
        assert_eq!(e, 2f64.powi(-60));
    }

    #[test]
    fn catastrophic_cancellation_survives() {
        // (1e16 + 1) - 1e16 = 1 exactly in dd, 0 in plain f64 summation
        // order (1e16 + 1 rounds to 1e16... actually 1e16+1 is exactly
        // representable; use a harder case).
        let big = 2f64.powi(60);
        let x = Dd::from_f64(big).add_f64(1.0).add_f64(-big);
        assert_eq!(x.to_f64(), 1.0);
    }

    #[test]
    fn dot_matches_analytic() {
        // sum_{i=1..n} i * (1/i) = n, exactly.
        let n = 1000;
        let a: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let b: Vec<f64> = (1..=n).map(|i| 1.0 / i as f64).collect();
        let d = Dd::dot(&a, &b);
        // Each term i*(1/i) has rounding in 1/i, so exact equality with n
        // isn't expected — but dd must match a Kahan-style exact model far
        // beyond f64: compare against f64 dot done in reverse order.
        let fwd: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((d.to_f64() - fwd).abs() < 1e-12 * n as f64);
        assert!((d.to_f64() - n as f64).abs() < 1e-10);
    }

    #[test]
    fn dd_resolution_exceeds_f64() {
        // dd can represent 1 + 2^-100.
        let tiny = 2f64.powi(-100);
        let x = Dd::ONE.add_f64(tiny);
        assert_eq!(x.hi, 1.0);
        assert_eq!(x.lo, tiny);
        let diff = x.sub(Dd::ONE);
        assert_eq!(diff.to_f64(), tiny);
    }

    #[test]
    fn div_accuracy() {
        let x = Dd::from_f64(1.0).div(Dd::from_f64(3.0));
        let back = x.mul_f64(3.0);
        assert!((back.to_f64() - 1.0).abs() < 1e-31);
        assert!((x.hi - 1.0 / 3.0).abs() < 1e-16);
    }

    #[test]
    fn sum_of_many_tiny_terms() {
        // 2^20 copies of 2^-60 summed into 1.0: plain f64 loses them all
        // when added to 1 first; dd keeps every bit.
        let mut acc = Dd::ONE;
        let tiny = 2f64.powi(-60);
        for _ in 0..(1 << 20) {
            acc = acc.add_f64(tiny);
        }
        let expect = 1.0 + 2f64.powi(-40);
        assert_eq!(acc.to_f64(), expect);
    }
}
