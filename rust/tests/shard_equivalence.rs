//! The sharding differential harness: shards ∈ {1, 2, 4} × partition
//! policies × steal on/off must produce **bitwise-identical** outputs,
//! verdicts and per-row thresholds for the same seeds.
//!
//! This is the contract that makes the serving tier safe to scale:
//! sharding, NUMA partitioning and work stealing are *pure scheduling* —
//! they decide where a request executes, never what it computes — so
//! every calibrated e_max and every verification decision carries over
//! unchanged from the single-queue coordinator. A divergence here means
//! a scheduling knob leaked into the rounding schedule, which would
//! silently invalidate the paper's threshold model in production.
//!
//! The request mix deliberately exercises every observation channel:
//! mixed activation shapes, clean and injected requests (output, operand
//! and checksum fault sites), id-based and handle-based submission, and
//! both monolithic and blockwise weight preparation.

use std::sync::Arc;

use vabft::abft::FtGemmOutput;
use vabft::coordinator::{
    Coordinator, CoordinatorConfig, GemmRequest, InjectSpec, PartitionPolicy,
    PreparedGemmRequest, TopologyConfig,
};
use vabft::planner::{PlanMode, ProtectionPlan, ProtectionScheme};
use vabft::prelude::*;
use vabft::workload::{
    arrival_times, build_trace, run_open_loop, run_replay, run_replay_planned, ArrivalModel,
    OpenLoopConfig, ReplayConfig,
};

const K: usize = 64;
const N: usize = 48;

/// Everything a response exposes that the contract covers, with floats
/// captured as raw bits (equality must be bitwise, not approximate).
#[derive(Debug, Clone, PartialEq, Eq)]
struct Obs {
    id: u64,
    /// Output matrix bits, row-major (empty for errored requests).
    c_bits: Vec<u64>,
    /// Error string for failed requests (None on success).
    err: Option<String>,
    verdict: Option<u8>,
    /// Per-detection (row, localized col, D1 bits, D2 bits, threshold
    /// bits, severity bits, corrected, waived).
    detections: Vec<(usize, Option<usize>, u64, u64, u64, u64, bool, bool)>,
    rows_checked: usize,
    rows_recomputed: usize,
    /// Report-level threshold telemetry, as bits.
    min_threshold: u64,
    max_abs_d1: u64,
    /// Realized injected delta, as bits (0 when the request was clean).
    injected_delta: u64,
}

fn verdict_tag(v: Verdict) -> u8 {
    match v {
        Verdict::Clean => 0,
        Verdict::Corrected => 1,
        Verdict::Recomputed => 2,
        Verdict::Flagged => 3,
        Verdict::Waived => 4,
        Verdict::CorrectedGrid => 5,
    }
}

fn observe(id: u64, result: &Result<FtGemmOutput, String>, delta: Option<f64>) -> Obs {
    match result {
        Err(e) => Obs {
            id,
            c_bits: Vec::new(),
            err: Some(e.clone()),
            verdict: None,
            detections: Vec::new(),
            rows_checked: 0,
            rows_recomputed: 0,
            min_threshold: 0,
            max_abs_d1: 0,
            injected_delta: delta.unwrap_or(0.0).to_bits(),
        },
        Ok(out) => Obs {
            id,
            c_bits: out.c.data().iter().map(|v| v.to_bits()).collect(),
            err: None,
            verdict: Some(verdict_tag(out.report.verdict)),
            detections: out
                .report
                .detections
                .iter()
                .map(|d| {
                    let (d1, d2, t) = (d.d1.to_bits(), d.d2.to_bits(), d.threshold.to_bits());
                    (d.row, d.col, d1, d2, t, d.severity.to_bits(), d.corrected, d.waived)
                })
                .collect(),
            rows_checked: out.report.rows_checked,
            rows_recomputed: out.report.rows_recomputed,
            min_threshold: out.report.min_threshold.to_bits(),
            max_abs_d1: out.report.max_abs_d1.to_bits(),
            injected_delta: delta.unwrap_or(0.0).to_bits(),
        },
    }
}

fn weights(seed: u64) -> Matrix {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::sample_in(K, N, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

fn activation(seed: u64, m: usize) -> Matrix {
    let mut rng = Xoshiro256pp::from_stream(0x5EED, seed);
    Matrix::sample_in(m, K, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

/// Every fifth request carries an injection, cycling through the fault
/// sites (all above-threshold: exponent-class flips on the fused grid).
fn inject_for(i: usize) -> Option<InjectSpec> {
    if i % 5 != 4 {
        return None;
    }
    Some(match (i / 5) % 3 {
        0 => InjectSpec::output(i % 5, (7 * i) % N, 27),
        1 => InjectSpec::operand_a(i % 5, (3 * i) % K, (5 * i) % N, 12),
        _ => InjectSpec::checksum(i % 5, 26),
    })
}

/// Run the canonical seeded request mix through one coordinator
/// configuration and observe every response — plus the full per-row
/// threshold vectors the registered handle issues for each activation
/// shape (computed by the same pipeline implementation the responses
/// used).
fn run_config(
    shards: usize,
    partition: PartitionPolicy,
    steal: bool,
    block_k: Option<usize>,
) -> (Vec<Obs>, Vec<Vec<u64>>) {
    let c = Coordinator::start(CoordinatorConfig {
        workers: 2,
        queue_depth: 8, // smaller than the batch: exercises backpressure
        shards,
        partition,
        steal,
        block_k,
        // Synthetic topology: identical planning input everywhere, so
        // the only variables are the axes under test.
        topology: Some(TopologyConfig::uniform(2, 2)),
        ..Default::default()
    });
    let b = weights(1);
    let handle = c.register_weights(7, &b);

    // Mixed shapes: serving batches of 1, 5 and 8 rows.
    let shapes = [1usize, 5, 8];
    let mut pending = Vec::new();
    let mut injected = Vec::new();
    for i in 0..24usize {
        let a = activation(100 + i as u64, shapes[i % shapes.len()]);
        let inject = inject_for(i);
        injected.push(inject.clone());
        // Alternate id-based and handle-based submission.
        let (id, rx) = if i % 2 == 0 {
            c.submit_tagged(GemmRequest { a, weight: 7, inject })
        } else {
            c.submit_prepared_tagged(PreparedGemmRequest {
                a,
                weights: Arc::clone(&handle),
                inject,
            })
        };
        pending.push((id, rx));
    }

    let mut obs = Vec::new();
    for (i, (id, rx)) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("worker dropped reply");
        assert_eq!(resp.id, id, "response mis-routed");
        if injected[i].is_some() {
            assert!(resp.injected.is_some(), "injection outcome missing on request {i}");
        }
        obs.push(observe(id, &resp.result, resp.injected.map(|o| o.delta())));
    }

    // The per-row threshold vectors for each activation shape, exactly
    // as the pipeline issues them from this coordinator's prepared
    // state.
    let vab = VabftThreshold::default();
    let thresholds: Vec<Vec<u64>> = shapes
        .iter()
        .map(|&m| {
            let a = activation(100, m);
            handle
                .blocks()
                .iter()
                .flat_map(|blk| {
                    vab.thresholds_prepared(&a, &blk.stats, handle.ctx())
                        .into_iter()
                        .map(|t| t.to_bits())
                })
                .collect()
        })
        .collect();

    c.shutdown();
    (obs, thresholds)
}

#[test]
fn shards_partitions_and_steal_are_bitwise_equivalent() {
    let (reference, ref_thr) = run_config(1, PartitionPolicy::Contiguous, false, None);
    // The mix must actually exercise detection: some non-clean verdicts.
    assert!(
        reference.iter().any(|o| o.verdict.map(|v| v != 0).unwrap_or(false)),
        "request mix produced no detections — the harness lost its teeth"
    );
    assert!(reference.iter().all(|o| o.err.is_none()));
    for shards in [1usize, 2, 4] {
        for partition in [PartitionPolicy::Contiguous, PartitionPolicy::Interleaved] {
            for steal in [false, true] {
                let (got, thr) = run_config(shards, partition, steal, None);
                assert_eq!(
                    got, reference,
                    "divergence at shards={shards} partition={} steal={steal}",
                    partition.name()
                );
                assert_eq!(
                    thr, ref_thr,
                    "per-row thresholds diverged at shards={shards} partition={} steal={steal}",
                    partition.name()
                );
            }
        }
    }
}

#[test]
fn blockwise_prepared_weights_are_equally_shard_invariant() {
    // Same contract at block_k granularity (per-K-block thresholds):
    // K = 64 → 4 blocks of 16.
    let (reference, ref_thr) = run_config(1, PartitionPolicy::Contiguous, false, Some(16));
    assert!(reference.iter().all(|o| o.rows_checked % 4 == 0), "expected 4 K-blocks per check");
    for (shards, partition, steal) in [
        (2usize, PartitionPolicy::Interleaved, true),
        (4, PartitionPolicy::Contiguous, true),
        (4, PartitionPolicy::Interleaved, false),
    ] {
        let (got, thr) = run_config(shards, partition, steal, Some(16));
        assert_eq!(
            got, reference,
            "blockwise divergence at shards={shards} partition={} steal={steal}",
            partition.name()
        );
        assert_eq!(thr, ref_thr);
    }
}

#[test]
fn replay_fingerprint_is_shard_invariant() {
    // The workload-level restatement: a whole transformer-layer replay's
    // output fingerprint (every response's bits + verdict, in order) is
    // identical across shard configurations.
    let cfg = ReplayConfig::smoke("gpt2", 0xFACE);
    let run = |shards: usize, partition: PartitionPolicy, steal: bool| {
        run_replay(
            &cfg,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 16,
                shards,
                partition,
                steal,
                topology: Some(TopologyConfig::uniform(2, 2)),
                ..Default::default()
            },
        )
    };
    let base = run(1, PartitionPolicy::Contiguous, false);
    assert_eq!(base.faulty, 0);
    for (shards, partition, steal) in [
        (2usize, PartitionPolicy::Contiguous, true),
        (2, PartitionPolicy::Interleaved, false),
        (4, PartitionPolicy::Interleaved, true),
    ] {
        let r = run(shards, partition, steal);
        assert_eq!(
            r.fingerprint,
            base.fingerprint,
            "replay fingerprint diverged at shards={shards} partition={} steal={steal}",
            partition.name()
        );
        assert_eq!(r.requests, base.requests);
        assert_eq!(r.faulty, 0);
    }
}

/// The protection-plan restatement of the sharding contract (invariant
/// #9): a replay whose weights are registered under an explicit *mixed*
/// plan — full, fused, grid and replicate schemes cycling across the
/// trace's layers — must produce (a) the same fingerprint at every shard
/// count and (b) the *uniform* replay's fingerprint, because every
/// scheme the default planner emits preserves each output element's
/// rounding schedule. Plan dispatch decides which verifier runs, never
/// what the GEMM computes.
#[test]
fn mixed_protection_plan_replay_is_shard_invariant_and_matches_uniform() {
    let cfg = ReplayConfig::smoke("gpt2", 0xFACE);
    let trace = build_trace(&cfg);
    let mut plan = ProtectionPlan::uniform_for(&trace);
    plan.mode = PlanMode::Auto;
    let cycle = [
        ProtectionScheme::Full,
        ProtectionScheme::Fused,
        ProtectionScheme::Grid,
        ProtectionScheme::Replicate,
    ];
    assert!(
        plan.entries.len() >= cycle.len(),
        "trace too small to exercise every neutral scheme: {} weights",
        plan.entries.len()
    );
    for (i, e) in plan.entries.iter_mut().enumerate() {
        e.scheme = cycle[i % cycle.len()];
    }

    let run = |shards: usize, plan: Option<&ProtectionPlan>| {
        run_replay_planned(
            &cfg,
            CoordinatorConfig {
                workers: 2,
                queue_depth: 16,
                shards,
                topology: Some(TopologyConfig::uniform(2, 2)),
                ..Default::default()
            },
            plan,
        )
    };
    let uniform = run(1, None);
    assert_eq!(uniform.faulty, 0);
    let base = run(1, Some(&plan));
    assert_eq!(base.faulty, 0, "clean replay flagged under a mixed plan");
    assert_eq!(
        base.fingerprint, uniform.fingerprint,
        "a neutral mixed plan must be invisible in output bits (invariant #9)"
    );
    for shards in [2usize, 4] {
        let r = run(shards, Some(&plan));
        assert_eq!(
            r.fingerprint, base.fingerprint,
            "mixed-plan fingerprint diverged at shards={shards}"
        );
        assert_eq!(r.requests, base.requests);
        assert_eq!(r.faulty, 0);
    }
}

/// Block-K is the one plan scheme that is *not* schedule-neutral
/// (per-K-block aggregation is a different rounding schedule, documented
/// on `VerifyGranularity`), so its fingerprint may legitimately differ
/// from the uniform replay's — but it must still be identical across
/// shard counts: the data-path choice rides the weight handle, and
/// scheduling still never touches it.
#[test]
fn block_k_plan_replay_is_shard_invariant() {
    let cfg = ReplayConfig::smoke("gpt2", 0xFACE);
    let trace = build_trace(&cfg);
    let mut plan = ProtectionPlan::uniform_for(&trace);
    plan.mode = PlanMode::Auto;
    for e in plan.entries.iter_mut() {
        e.scheme = ProtectionScheme::BlockK((e.k / 4).max(1));
    }
    let run = |shards: usize| {
        run_replay_planned(
            &cfg,
            CoordinatorConfig {
                workers: 2,
                queue_depth: 16,
                shards,
                topology: Some(TopologyConfig::uniform(2, 2)),
                ..Default::default()
            },
            Some(&plan),
        )
    };
    let base = run(1);
    assert_eq!(base.faulty, 0, "clean replay flagged under a block-K plan");
    for shards in [2usize, 4] {
        let r = run(shards);
        assert_eq!(
            r.fingerprint, base.fingerprint,
            "block-K plan fingerprint diverged at shards={shards}"
        );
        assert_eq!(r.faulty, 0);
    }
}

#[test]
fn arrival_generator_is_a_pure_function_of_seed() {
    // The pre-execution half of the open-loop contract, restated at the
    // integration level: the request clock depends on nothing but
    // `(model, rate, n, seed)` — no global state, no wall time.
    for model in ArrivalModel::all() {
        let a = arrival_times(model, 800.0, 256, 0xA1);
        assert_eq!(a, arrival_times(model, 800.0, 256, 0xA1), "{} drifted", model.name());
        assert_ne!(
            a,
            arrival_times(model, 800.0, 256, 0xA2),
            "{} ignored its seed",
            model.name()
        );
        assert_eq!(a.len(), 256);
    }
}

#[test]
fn open_loop_schedule_and_outputs_are_shard_invariant() {
    // The open-loop restatement of the sharding contract, across the
    // full grid shards × partition × steal × verify point, on a
    // mixed-family trace that includes the faulted recovery path. Queues
    // run deeper than the offered count so shedding — the one
    // timing-dependent outcome in the open loop — is impossible, making
    // every fingerprint exact. The fused epilogue rides the same grid:
    // moving verification into the kernel must not move a single bit.
    let mut cfg = OpenLoopConfig::smoke(0xBEA7);
    cfg.requests = 30;
    cfg.fault_every = 6;
    let run = |shards: usize, partition: PartitionPolicy, steal: bool, fused: bool| {
        run_open_loop(
            &cfg,
            CoordinatorConfig {
                workers: 2,
                queue_depth: cfg.requests,
                shards,
                partition,
                steal,
                policy: if fused { VerifyPolicy::fused() } else { VerifyPolicy::default() },
                topology: Some(TopologyConfig::uniform(2, 2)),
                ..Default::default()
            },
        )
    };
    let base = run(1, PartitionPolicy::Contiguous, false, false);
    assert_eq!(base.replay.shed, 0);
    assert!(base.faults_detected > 0, "fault cadence produced no detections");
    for shards in [1usize, 2, 4] {
        for partition in [PartitionPolicy::Contiguous, PartitionPolicy::Interleaved] {
            for steal in [false, true] {
                for fused in [false, true] {
                    let r = run(shards, partition, steal, fused);
                    let tag = format!(
                        "shards={shards} partition={} steal={steal} fused={fused}",
                        partition.name()
                    );
                    assert_eq!(r.replay.shed, 0, "deep queues shed at {tag}");
                    assert_eq!(r.offered, cfg.requests, "offered count wrong at {tag}");
                    assert_eq!(
                        r.trace_fingerprint, base.trace_fingerprint,
                        "schedule diverged at {tag}"
                    );
                    assert_eq!(
                        r.replay.fingerprint, base.replay.fingerprint,
                        "response fingerprint diverged at {tag}"
                    );
                    assert_eq!(
                        r.output_fingerprint, base.output_fingerprint,
                        "output bits diverged at {tag}"
                    );
                    assert_eq!(
                        r.faults_detected, base.faults_detected,
                        "detection count diverged at {tag}"
                    );
                }
            }
        }
    }
}
