//! Cross-module integration: encode → modelled GEMM → threshold → verify
//! → localize → correct, across precisions, distributions and policies.

use vabft::prelude::*;
use vabft::gemm::ReduceStrategy;
use vabft::threshold::{AabftThreshold, ThresholdContext};

fn operands(seed: u64, m: usize, k: usize, n: usize, d: &Distribution) -> (Matrix, Matrix) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (Matrix::sample(m, k, d, &mut rng), Matrix::sample(k, n, d, &mut rng))
}

fn all_models() -> Vec<AccumModel> {
    vec![
        AccumModel::cpu(Precision::F64),
        AccumModel::cpu(Precision::F32),
        AccumModel::gpu_highprec(Precision::F64),
        AccumModel::gpu_highprec(Precision::F32),
        AccumModel::wide(Precision::Bf16),
        AccumModel::wide(Precision::F16),
        AccumModel::fp8(Precision::F8E4M3),
    ]
}

#[test]
fn clean_multiplies_verify_clean_across_models_and_distributions() {
    let dists = [
        Distribution::near_zero_normal(),
        Distribution::normal_1_1(),
        Distribution::uniform_pm1(),
        Distribution::truncated_normal(),
    ];
    for model in all_models() {
        for (di, d) in dists.iter().enumerate() {
            for policy in [VerifyPolicy::default(), VerifyPolicy::offline()] {
                let ft = FtGemm::new(
                    GemmEngine::new(model),
                    Box::new(VabftThreshold::default()),
                    policy,
                );
                let (a, b) = operands(40 + di as u64, 24, 48, 32, d);
                let out = ft.multiply(&a, &b).unwrap();
                assert_eq!(
                    out.report.verdict,
                    Verdict::Clean,
                    "{:?} {} online={} — {:?}",
                    model,
                    d.label(),
                    policy.online,
                    out.report.detections.first()
                );
            }
        }
    }
}

#[test]
fn exponent_flips_recovered_end_to_end_bf16() {
    // The paper's core story at system level: BF16 GEMM + online V-ABFT
    // catches exponent-bit flips and repairs them in place.
    let model = AccumModel::wide(Precision::Bf16);
    let ft = FtGemm::new(
        GemmEngine::new(model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::default(),
    );
    let d = Distribution::normal_1_1();
    let mut recovered = 0;
    let trials = 40;
    for t in 0..trials {
        let (a, b) = operands(100 + t, 16, 64, 24, &d);
        let clean = ft.multiply(&a, &b).unwrap().c;
        let mut rng = Xoshiro256pp::seed_from_u64(900 + t);
        let site = InjectionSite {
            row: rng.uniform_u64(16) as usize,
            col: rng.uniform_u64(24) as usize,
        };
        // exponent bits 10..14 on the FP32 accumulator view
        let bit = 23 + rng.uniform_u64(5) as u32; // f32 exponent bits 23..27
        let out = ft
            .multiply_with_injection(&a, &b, |o| {
                let flip = BitFlip::new(bit, Precision::F32);
                let old = o.acc.get(site.row, site.col);
                let (new, _) = flip.apply(old);
                o.acc.set(site.row, site.col, new);
                o.c.set(site.row, site.col, Precision::Bf16.quantize(new));
            })
            .unwrap();
        assert_ne!(out.report.verdict, Verdict::Clean, "trial {t}: missed");
        if out.c.max_abs_diff(&clean) < 1e-2 {
            recovered += 1;
        }
    }
    assert!(
        recovered >= trials - 2,
        "only {recovered}/{trials} recovered to the clean product"
    );
}

#[test]
fn online_detects_faults_far_below_offline_threshold() {
    // §3.6's 1000× granularity: a fault of magnitude ~100·u_f32·|C| is
    // invisible to offline BF16 verification but caught online.
    let model = AccumModel::wide(Precision::Bf16);
    let online = FtGemm::new(
        GemmEngine::new(model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::detect_only(true),
    );
    let offline = FtGemm::new(
        GemmEngine::new(model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::detect_only(false),
    );
    let d = Distribution::uniform_01();
    let (a, b) = operands(7, 8, 128, 64, &d);
    // fault magnitude: tiny vs the BF16-scale offline threshold
    // (≈ 2·u_bf16·|checksum| ≈ 10), clear vs FP32 verification noise
    // (online threshold ≈ 1e-3).
    let delta = 0.05;
    let mut caught_online = 0;
    let mut caught_offline = 0;
    for t in 0..10 {
        let site = InjectionSite { row: t % 8, col: (3 * t) % 64 };
        let inject = |o: &mut vabft::gemm::GemmOutput| {
            let v = o.acc.get(site.row, site.col);
            o.acc.set(site.row, site.col, v + delta);
            o.c.set(site.row, site.col, Precision::Bf16.quantize(v + delta));
        };
        if online.multiply_with_injection(&a, &b, inject).unwrap().report.verdict
            != Verdict::Clean
        {
            caught_online += 1;
        }
        let inject2 = |o: &mut vabft::gemm::GemmOutput| {
            let v = o.acc.get(site.row, site.col);
            o.acc.set(site.row, site.col, v + delta);
            o.c.set(site.row, site.col, Precision::Bf16.quantize(v + delta));
        };
        if offline.multiply_with_injection(&a, &b, inject2).unwrap().report.verdict
            != Verdict::Clean
        {
            caught_offline += 1;
        }
    }
    assert!(caught_online >= 8, "online caught only {caught_online}/10");
    assert!(
        caught_offline <= 2,
        "offline should miss sub-BF16 faults, caught {caught_offline}/10"
    );
}

#[test]
fn aabft_baseline_also_detects_but_with_larger_thresholds() {
    let model = AccumModel::gpu_highprec(Precision::F32);
    let d = Distribution::uniform_pm1();
    let (a, b) = operands(8, 16, 128, 128, &d);
    let ctx = ThresholdContext::offline(model);
    let v = VabftThreshold::default().thresholds(&a, &b, &ctx);
    let aa = AabftThreshold::paper_repro().thresholds(&a, &b, &ctx);
    // A-ABFT threshold strictly larger (the paper's Table 5 gap; the gap
    // here is smaller than the paper's because the default context uses
    // the conservative rule-based e_max — the T5 bench uses the Table 7
    // calibrated values and reproduces the full 321×-vs-13× spread).
    for i in 0..16 {
        assert!(aa[i] > v[i] * 2.0, "row {i}: A {} vs V {}", aa[i], v[i]);
    }
    // but both catch a 1.0-magnitude upset
    let ft = FtGemm::new(
        GemmEngine::new(model),
        Box::new(AabftThreshold::paper_repro()),
        VerifyPolicy::default(),
    );
    let out = ft
        .multiply_with_injection(&a, &b, |o| {
            let x = o.acc.get(2, 2);
            o.acc.set(2, 2, x + 1.0);
            o.c.set(2, 2, (x + 1.0_f64) as f32 as f64);
        })
        .unwrap();
    assert_ne!(out.report.verdict, Verdict::Clean);
}

// ---------------------------------------------------------------------
// Correction round-trip regressions: for each precision × strategy, a
// single above-threshold flip must be repaired to the *bitwise* fault-free
// output, and the coordinator's metrics must match the verdict.
//
// Two repair regimes, by construction of the pipeline:
//
// * **Corrected** (wide/FP8 models): online correction subtracts D1 on
//   the FP32 accumulator; the residual is verification rounding noise
//   (~u_f32·|rowsum|), which the coarse output rounding absorbs — the
//   corrected element re-rounds to exactly the clean output value.
// * **Recomputed** (models whose output grid *is* the verify grid, so
//   correction noise would survive): a recompute-only policy re-executes
//   the flagged row on the same engine, and schedule preservation makes
//   the recomputed row bitwise-identical to the clean run.
// ---------------------------------------------------------------------

use vabft::coordinator::{Coordinator, CoordinatorConfig, GemmRequest, InjectSpec};

/// Run one (model, policy) case through a fresh coordinator: clean
/// request, then the same activation with a single above-threshold
/// output flip. `bit` must address an exponent bit of the model's
/// verify grid (the FP32 work grid for wide models, the native grid
/// otherwise); the strike lands on row 2's largest-magnitude element,
/// so the realized |δ| is at least ~0.75× that element — orders of
/// magnitude above the online threshold. Returns (clean output,
/// faulty-run output, verdict, detections, recomputed, snapshot).
fn round_trip(
    model: AccumModel,
    policy: VerifyPolicy,
    bit: u32,
    seed: u64,
) -> (Matrix, Matrix, Verdict, usize, usize, vabft::metrics::MetricsSnapshot) {
    let c = Coordinator::start(CoordinatorConfig {
        workers: 1,
        model,
        policy,
        ..Default::default()
    });
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let d = Distribution::normal_1_1();
    let b = Matrix::sample_in(48, 24, &d, model.input, &mut rng);
    let a = Matrix::sample_in(6, 48, &d, model.input, &mut rng);
    c.register_weight(1, &b);
    let clean = c
        .call(GemmRequest { a: a.clone(), weight: 1, inject: None })
        .result
        .expect("clean run failed");
    assert_eq!(clean.report.verdict, Verdict::Clean, "{model:?}: clean run flagged");
    // Strike the row's largest element: maximal detection margin.
    let row = 2usize;
    let col = (0..clean.c.cols())
        .max_by(|&x, &y| {
            clean.c.get(row, x).abs().partial_cmp(&clean.c.get(row, y).abs()).unwrap()
        })
        .unwrap();
    let faulty = c
        .call(GemmRequest { a, weight: 1, inject: Some(InjectSpec::output(row, col, bit)) })
        .result
        .expect("faulty run failed");
    let snap = c.metrics().snapshot();
    let verdict = faulty.report.verdict;
    let detections = faulty.report.detections.len();
    let recomputed = faulty.report.rows_recomputed;
    c.shutdown();
    (clean.c, faulty.c, verdict, detections, recomputed, snap)
}

/// Every strategy applied to a base accumulation model.
fn with_strategies(base: AccumModel) -> Vec<AccumModel> {
    [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        .into_iter()
        .map(|strategy| AccumModel { strategy, ..base })
        .collect()
}

#[test]
fn correction_round_trip_is_bitwise_for_wide_models() {
    let mut seed = 700;
    for base in [
        AccumModel::wide(Precision::Bf16),
        AccumModel::wide(Precision::F16),
        AccumModel::fp8(Precision::F8E4M3),
    ] {
        for model in with_strategies(base) {
            seed += 1;
            // Bit 24 = FP32 exponent bit 1: rescales the struck value by
            // 2^±2 (|δ| ≥ 0.75·|v|) while keeping the faulty row sum
            // small enough that D1's own rounding noise stays far below
            // the output grid's ulp — so correction restores the exact
            // clean output bits.
            let (clean, repaired, verdict, detections, recomputed, m) =
                round_trip(model, VerifyPolicy::default(), 24, seed);
            assert_eq!(verdict, Verdict::Corrected, "{model:?}");
            assert_eq!(detections, 1, "{model:?}: one upset, one detection");
            assert_eq!(recomputed, 0, "{model:?}");
            assert_eq!(
                repaired.data(),
                clean.data(),
                "{model:?}: corrected output must be bitwise-equal to the fault-free run"
            );
            // Metrics must match the verdict exactly.
            assert_eq!(m.faults_detected, 1, "{model:?}");
            assert_eq!(m.faults_corrected, 1, "{model:?}");
            assert_eq!(m.rows_recomputed, 0, "{model:?}");
            assert_eq!(m.jobs_completed, 2, "{model:?}");
        }
    }
}

#[test]
fn recompute_round_trip_is_bitwise_for_full_precision_models() {
    // Recompute-only policy: correction noise on a same-grid output
    // could never be bitwise, recomputation always is.
    let policy = VerifyPolicy {
        online: true,
        fused: false,
        correct: false,
        recompute: true,
        reverify: false,
        localize_tol: 0.45,
        severity: false,
        encoding: EncodingMode::RowOnly,
        granularity: VerifyGranularity::Monolithic,
    };
    let mut seed = 800;
    // Exponent bit 1 of each model's verify grid: bit 24 on FP32,
    // bit 53 on FP64.
    for (base, bit) in [
        (AccumModel::gpu_highprec(Precision::F32), 24u32),
        (AccumModel::cpu(Precision::F64), 53),
    ] {
        for model in with_strategies(base) {
            seed += 1;
            let (clean, repaired, verdict, detections, recomputed, m) =
                round_trip(model, policy, bit, seed);
            assert_eq!(verdict, Verdict::Recomputed, "{model:?}");
            assert_eq!(detections, 1, "{model:?}");
            assert_eq!(recomputed, 1, "{model:?}");
            assert_eq!(
                repaired.data(),
                clean.data(),
                "{model:?}: recomputed output must be bitwise-equal to the fault-free run"
            );
            assert_eq!(m.faults_detected, 1, "{model:?}");
            assert_eq!(m.faults_corrected, 0, "{model:?}");
            assert_eq!(m.rows_recomputed, 1, "{model:?}");
        }
    }
}

#[test]
fn fused_correction_round_trip_is_bitwise_for_wide_models() {
    // The fused-epilogue counterpart of the staged matrix above: the
    // PR that moved verification into the packed GEMM epilogue pinned
    // decision equality, but not the correction round-trip itself.
    // Same contract, per precision × strategy, under
    // `VerifyPolicy::fused()`.
    let mut seed = 900;
    for base in [
        AccumModel::wide(Precision::Bf16),
        AccumModel::wide(Precision::F16),
        AccumModel::fp8(Precision::F8E4M3),
    ] {
        for model in with_strategies(base) {
            seed += 1;
            let (clean, repaired, verdict, detections, recomputed, m) =
                round_trip(model, VerifyPolicy::fused(), 24, seed);
            assert_eq!(verdict, Verdict::Corrected, "{model:?} (fused)");
            assert_eq!(detections, 1, "{model:?} (fused): one upset, one detection");
            assert_eq!(recomputed, 0, "{model:?} (fused)");
            assert_eq!(
                repaired.data(),
                clean.data(),
                "{model:?}: fused-path correction must be bitwise-equal to the fault-free run"
            );
            assert_eq!(m.faults_detected, 1, "{model:?} (fused)");
            assert_eq!(m.faults_corrected, 1, "{model:?} (fused)");
            assert_eq!(m.rows_recomputed, 0, "{model:?} (fused)");
            assert_eq!(m.jobs_completed, 2, "{model:?} (fused)");
        }
    }
}

#[test]
fn fused_recompute_round_trip_is_bitwise_for_full_precision_models() {
    // Recompute-only under the fused epilogue: schedule preservation
    // must make the recomputed row bitwise-identical whether detection
    // ran staged or in-epilogue.
    let policy = VerifyPolicy {
        online: true,
        fused: true,
        correct: false,
        recompute: true,
        reverify: false,
        localize_tol: 0.45,
        severity: false,
        encoding: EncodingMode::RowOnly,
        granularity: VerifyGranularity::Monolithic,
    };
    let mut seed = 950;
    for (base, bit) in [
        (AccumModel::gpu_highprec(Precision::F32), 24u32),
        (AccumModel::cpu(Precision::F64), 53),
    ] {
        for model in with_strategies(base) {
            seed += 1;
            let (clean, repaired, verdict, detections, recomputed, m) =
                round_trip(model, policy, bit, seed);
            assert_eq!(verdict, Verdict::Recomputed, "{model:?} (fused)");
            assert_eq!(detections, 1, "{model:?} (fused)");
            assert_eq!(recomputed, 1, "{model:?} (fused)");
            assert_eq!(
                repaired.data(),
                clean.data(),
                "{model:?}: fused-path recompute must be bitwise-equal to the fault-free run"
            );
            assert_eq!(m.faults_detected, 1, "{model:?} (fused)");
            assert_eq!(m.faults_corrected, 0, "{model:?} (fused)");
            assert_eq!(m.rows_recomputed, 1, "{model:?} (fused)");
        }
    }
}

// ---------------------------------------------------------------------
// Severity-aware recovery: a detection whose residual is provably below
// output-quantization noise (|D1| ≤ u_out · Σ|row|) skips the recompute
// escalation; everything above that bound still recomputes. Detection
// itself is untouched by the policy — severity only decides the repair.
// ---------------------------------------------------------------------

/// Two equal perturbations in one row at columns whose syndrome midpoint
/// falls between localization weights: detected (|D1| = 2δ above the
/// online threshold), never localizable (D2/D1 lands ~0.5 from every
/// integer weight), so the pipeline reaches the recompute/waive branch
/// with residual exactly 2δ.
fn two_site_injection(
    model: AccumModel,
    policy: VerifyPolicy,
    delta: f64,
) -> (Matrix, vabft::abft::FtGemmOutput) {
    let ft = FtGemm::new(GemmEngine::new(model), Box::new(VabftThreshold::default()), policy);
    let d = Distribution::uniform_01();
    let (a, b) = operands(21, 8, 128, 64, &d);
    let clean = ft.multiply(&a, &b).unwrap();
    assert_eq!(clean.report.verdict, Verdict::Clean);
    let out = ft
        .multiply_with_injection(&a, &b, |o| {
            for col in [3usize, 6] {
                let v = o.acc.get(2, col);
                o.acc.set(2, col, v + delta);
                o.c.set(2, col, model.out.quantize(v + delta));
            }
        })
        .unwrap();
    (clean.c, out)
}

#[test]
fn severity_waives_sub_quantization_residuals_instead_of_recomputing() {
    // uniform-01 operands, K=128: row elements ≈ 32, Σ|row| ≈ 2048, so
    // the waiver bound u_bf16 · Σ|row| ≈ 4 — while the online threshold
    // sits near 1e-3. δ = 0.01 per site puts |D1| ≈ 0.02 well above
    // detection and well below the bound.
    let model = AccumModel::wide(Precision::Bf16);
    let (clean, out) = two_site_injection(model, VerifyPolicy::default().with_severity(), 0.01);
    assert_eq!(out.report.verdict, Verdict::Waived);
    assert_eq!(out.report.rows_recomputed, 0, "waived row must not be recomputed");
    assert_eq!(out.report.rows_waived, 1);
    let det = &out.report.detections[0];
    assert!(det.waived && !det.corrected);
    assert!(det.severity >= 1.0, "a detection is at least at the threshold floor");
    // The retained error is bounded by one output-grid ulp per element
    // (that is the whole point of waiving).
    assert!(
        out.c.max_abs_diff(&clean) < 0.5,
        "waived residual exceeded output quantization noise: {}",
        out.c.max_abs_diff(&clean)
    );

    // The identical fault without the severity policy escalates.
    let (clean2, strict) = two_site_injection(model, VerifyPolicy::default(), 0.01);
    assert_eq!(strict.report.verdict, Verdict::Recomputed);
    assert_eq!(strict.report.rows_waived, 0);
    assert_eq!(strict.c.data(), clean2.data(), "recompute restores the clean bits");
}

#[test]
fn severity_never_waives_above_noise_faults() {
    // δ = 50 per site: |D1| ≈ 100 ≫ u_bf16 · Σ|row| ≈ 4. The severity
    // policy must take the same recompute path as the strict one and
    // restore the clean bits exactly.
    let model = AccumModel::wide(Precision::Bf16);
    let (clean, out) = two_site_injection(model, VerifyPolicy::default().with_severity(), 50.0);
    assert_eq!(out.report.verdict, Verdict::Recomputed);
    assert_eq!(out.report.rows_waived, 0, "above-noise fault must never be waived");
    assert_eq!(out.report.rows_recomputed, 1);
    assert_eq!(out.c.data(), clean.data(), "recomputed output must be bitwise clean");
}

// ---------------------------------------------------------------------
// Multi-fault round-trips: two simultaneous upsets per trial. Operands
// are small integers (|a|,|b| ≤ 1, K = 48), so every sum in every
// model's work grid is exact — syndromes recover injected deltas
// exactly and corrections restore the clean accumulator bitwise, for
// ALL precisions at once.
//
// * Same-row pair: D2/D1 lands exactly halfway between localization
//   weights, so the single-checksum (row-only) policy cannot localize
//   and must recompute. The grid encoding intersects the column
//   syndromes (one fault per column → each localizes its row), peels
//   both upsets and returns `CorrectedGrid` with zero recomputes.
// * Same-column pair: one fault per row, so the row direction corrects
//   both under every encoding — the control showing the grid machinery
//   changes nothing where row checksums already suffice.
// ---------------------------------------------------------------------

fn integer_operands(m: usize, k: usize, n: usize) -> (Matrix, Matrix) {
    let a = Matrix::from_fn(m, k, |i, j| ((i * 7 + j * 3) % 3) as f64 - 1.0);
    let b = Matrix::from_fn(k, n, |i, j| ((i * 5 + j * 11) % 3) as f64 - 1.0);
    (a, b)
}

#[test]
fn two_faults_same_row_recompute_vs_grid_correction() {
    for model in all_models() {
        let (a, b) = integer_operands(6, 48, 8);
        // Columns 3 and 7, deltas +3 and +5: D1 = 8, D2 = 4·3 + 8·5 = 52,
        // ratio 6.5 → fractional part exactly 0.5 > localize_tol — the
        // engineered row-inconsistent pattern.
        for (policy, expect, recomputes) in [
            (VerifyPolicy::default(), Verdict::Recomputed, 1usize),
            (VerifyPolicy::grid(), Verdict::CorrectedGrid, 0),
        ] {
            let ft = FtGemm::new(
                GemmEngine::new(model),
                Box::new(VabftThreshold::default()),
                policy,
            );
            let clean = ft.multiply(&a, &b).unwrap();
            assert_eq!(clean.report.verdict, Verdict::Clean, "{model:?} {:?}", policy.encoding);
            let out = ft
                .multiply_with_injection(&a, &b, |o| {
                    let v3 = o.acc.get(2, 3);
                    o.acc.set(2, 3, v3 + 3.0);
                    let v7 = o.acc.get(2, 7);
                    o.acc.set(2, 7, v7 + 5.0);
                })
                .unwrap();
            assert_eq!(out.report.verdict, expect, "{model:?} {:?}", policy.encoding);
            assert_eq!(
                out.report.rows_recomputed, recomputes,
                "{model:?} {:?}",
                policy.encoding
            );
            assert_eq!(
                out.report.inconsistent_localizations, 1,
                "{model:?} {:?}: the same-row pair must register as row-inconsistent",
                policy.encoding
            );
            if expect == Verdict::CorrectedGrid {
                assert_eq!(out.report.rows_corrected_grid, 1, "{model:?}");
            }
            assert_eq!(
                out.c.data(),
                clean.c.data(),
                "{model:?} {:?}: repaired output must be bitwise-equal to the fault-free run",
                policy.encoding
            );
        }
    }
}

#[test]
fn two_faults_same_column_correct_under_every_encoding() {
    for model in all_models() {
        let (a, b) = integer_operands(6, 48, 8);
        for policy in [VerifyPolicy::default(), VerifyPolicy::grid()] {
            let ft = FtGemm::new(
                GemmEngine::new(model),
                Box::new(VabftThreshold::default()),
                policy,
            );
            let clean = ft.multiply(&a, &b).unwrap();
            assert_eq!(clean.report.verdict, Verdict::Clean, "{model:?} {:?}", policy.encoding);
            let out = ft
                .multiply_with_injection(&a, &b, |o| {
                    for row in [1usize, 4] {
                        let v = o.acc.get(row, 5);
                        o.acc.set(row, 5, v + 4.0);
                    }
                })
                .unwrap();
            // One fault per row → plain row-direction correction, no
            // recompute, no grid escalation, under both encodings.
            assert_eq!(out.report.verdict, Verdict::Corrected, "{model:?} {:?}", policy.encoding);
            assert_eq!(out.report.detections.len(), 2, "{model:?} {:?}", policy.encoding);
            assert_eq!(out.report.rows_recomputed, 0, "{model:?} {:?}", policy.encoding);
            assert_eq!(out.report.rows_corrected_grid, 0, "{model:?} {:?}", policy.encoding);
            assert_eq!(
                out.c.data(),
                clean.c.data(),
                "{model:?} {:?}: corrected output must be bitwise-equal to the fault-free run",
                policy.encoding
            );
        }
    }
}

#[test]
fn strategy_changes_error_but_not_results_materially() {
    // Ablation: sequential vs pairwise vs fma give the same product to
    // within the model's error budget, but different verification noise.
    let d = Distribution::uniform_pm1();
    let (a, b) = operands(9, 8, 256, 64, &d);
    let mut cs = Vec::new();
    for strategy in [
        ReduceStrategy::Sequential,
        ReduceStrategy::Fma,
        ReduceStrategy::Pairwise,
    ] {
        let model = AccumModel {
            input: Precision::F32,
            work: Precision::F32,
            strategy,
            out: Precision::F32,
        };
        cs.push(GemmEngine::new(model).matmul(&a, &b).c);
    }
    for pair in cs.windows(2) {
        assert!(pair[0].max_abs_diff(&pair[1]) < 1e-3);
    }
}
