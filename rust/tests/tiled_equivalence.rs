//! Schedule-preservation property tests for the tiled/packed parallel
//! engine (hand-rolled generators — the proptest crate is not in the
//! offline registry; failing cases print their full configuration).
//!
//! The invariant V-ABFT depends on: for randomized (m, k, n, seed,
//! AccumModel, tile sizes, microkernel shapes, thread counts 1/2/4, and
//! every SIMD dispatch level this host can execute), the engine's output
//! **and** pre-quantization accumulator are *bitwise equal* to the naive
//! reference kernels, for all three `ReduceStrategy` variants. The reference is computed here from `gemm::kernels` /
//! `gemm::generic_gemm` directly — independently of the engine's dispatch
//! code — so a regression in either layer trips the test. The retained
//! PR-1 unpacked engine is cross-checked against the same reference,
//! giving two independent implementations that must agree with the
//! packed path everywhere.

use vabft::gemm::{
    generic_gemm, kernels, tiled, AccumModel, FusedProbe, GemmEngine, GemmOutput, MicroConfig,
    ParallelismConfig, ReduceStrategy, TileConfig,
};
use vabft::prelude::*;

struct Cases {
    rng: Xoshiro256pp,
}

impl Cases {
    fn new(seed: u64) -> Cases {
        Cases { rng: Xoshiro256pp::seed_from_u64(seed) }
    }

    fn dims(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.uniform_u64((hi - lo + 1) as u64) as usize
    }

    /// (input, work, out) triples covering all three kernel dispatch
    /// paths: native f64, native f32, and the generic soft-float path.
    fn precisions(&mut self) -> (Precision, Precision, Precision) {
        match self.rng.uniform_u64(6) {
            0 => (Precision::F64, Precision::F64, Precision::F64),
            1 => (Precision::F32, Precision::F32, Precision::F32),
            2 => (Precision::Bf16, Precision::F32, Precision::Bf16), // wide
            3 => (Precision::F16, Precision::F32, Precision::F16),   // wide
            4 => (Precision::F8E4M3, Precision::F32, Precision::F16), // fp8
            _ => (Precision::Bf16, Precision::Bf16, Precision::Bf16), // generic
        }
    }
}

/// The naive reference: input quantization + reference kernel + one output
/// rounding, mirroring the engine contract without touching its dispatch.
fn reference(model: AccumModel, a: &Matrix, b: &Matrix) -> (Vec<f64>, Vec<f64>) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let aq: Vec<f64> = a.data().iter().map(|&x| model.input.quantize(x)).collect();
    let bq: Vec<f64> = b.data().iter().map(|&x| model.input.quantize(x)).collect();
    let acc: Vec<f64> = match model.work {
        Precision::F64 => kernels::reference_gemm_f64(&aq, &bq, m, k, n, model.strategy),
        Precision::F32 => {
            let a32: Vec<f32> = aq.iter().map(|&x| x as f32).collect();
            let b32: Vec<f32> = bq.iter().map(|&x| x as f32).collect();
            kernels::reference_gemm_f32(&a32, &b32, m, k, n, model.strategy)
                .into_iter()
                .map(|x| x as f64)
                .collect()
        }
        other => generic_gemm(&aq, &bq, m, k, n, other, model.strategy),
    };
    let c: Vec<f64> = if model.out != model.work {
        acc.iter().map(|&x| model.out.quantize(x)).collect()
    } else {
        acc.clone()
    };
    (c, acc)
}

fn tile_grid() -> Vec<TileConfig> {
    vec![
        TileConfig::DEFAULT,
        TileConfig::new(1, 3, 5),  // degenerate tiny tiles, odd K blocks
        TileConfig::new(2, 7, 13), // ragged everything
        TileConfig::new(8, 64, 16),
    ]
}

fn micro_grid() -> Vec<MicroConfig> {
    vec![
        MicroConfig::DEFAULT,       // monomorphized 8x8
        MicroConfig::new(4, 8),     // monomorphized, asymmetric
        MicroConfig::new(1, 4),     // single-row panels
        MicroConfig::new(3, 5),     // dynamic-fallback kernel, coprime
        MicroConfig::new(16, 4),    // tall panels
    ]
}

#[test]
fn prop_tiled_engine_bitwise_equals_naive_reference() {
    let mut cases = Cases::new(0x711ED);
    let levels = SimdLevel::available_levels();
    for case in 0..24 {
        let (m, k, n) = (cases.dims(1, 12), cases.dims(1, 48), cases.dims(1, 32));
        let (input, work, out) = cases.precisions();
        let d = Distribution::normal_1_1();
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let model = AccumModel { input, work, strategy, out };
            let (want_c, want_acc) = reference(model, &a, &b);
            for threads in [1usize, 2, 4] {
                for tiles in tile_grid() {
                    let micro = micro_grid()[case % micro_grid().len()];
                    // Alternate the row-split policy across cases: both
                    // must be bitwise-equal to the reference.
                    let split = if case % 2 == 0 {
                        RowSplit::Contiguous
                    } else {
                        RowSplit::Interleaved
                    };
                    // Rotate the SIMD dispatch level across cases too:
                    // vectorization is per output column, so every level
                    // must reproduce the scalar bits.
                    let simd = levels[(case + threads) % levels.len()];
                    let par = ParallelismConfig { threads, tiles, micro, split, simd };
                    let got = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                    assert_eq!(
                        got.acc.data(),
                        want_acc.as_slice(),
                        "case {case}: acc diverged ({m}x{k}x{n}, {model:?}, {par:?})"
                    );
                    assert_eq!(
                        got.c.data(),
                        want_c.as_slice(),
                        "case {case}: c diverged ({m}x{k}x{n}, {model:?}, {par:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_packed_path_ragged_shapes() {
    // The packed-path edge-case zoo: dimensions coprime with every
    // default block size (MR/NR/mc/kc/nc), k = 0, n smaller than NR,
    // single row, single column, more threads than rows. Packed AND
    // unpacked engines vs the reference kernels, bitwise, f32 + f64.
    let shapes: &[(usize, usize, usize)] = &[
        (7, 61, 93),   // coprime with 8/8/64/256/128
        (13, 257, 31), // k just past default kc, n < default nc
        (1, 97, 257),  // single row, n crosses nc
        (9, 0, 5),     // k = 0
        (3, 31, 3),    // n < NR
        (2, 16, 1),    // single column
        (5, 129, 17),  // threads (up to 8) > m
    ];
    let mut cases = Cases::new(0x4A66ED);
    let levels = SimdLevel::available_levels();
    let d = Distribution::uniform_pm1();
    for &(m, k, n) in shapes {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        let a32: Vec<f32> = a.data().iter().map(|&x| x as f32).collect();
        let b32: Vec<f32> = b.data().iter().map(|&x| x as f32).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want64 = kernels::reference_gemm_f64(a.data(), b.data(), m, k, n, strategy);
            let want32 = kernels::reference_gemm_f32(&a32, &b32, m, k, n, strategy);
            for threads in [1usize, 2, 8] {
                for tiles in tile_grid() {
                    for micro in micro_grid() {
                        // Every SIMD level this host can run, on every
                        // ragged shape: dispatched microkernels must be
                        // bitwise-equal to the scalar path.
                        for &simd in &levels {
                            let split = if threads % 2 == 0 {
                                RowSplit::Interleaved
                            } else {
                                RowSplit::Contiguous
                            };
                            let par = ParallelismConfig { threads, tiles, micro, split, simd };
                            let got64 =
                                tiled::gemm_f64(a.data(), b.data(), m, k, n, strategy, &par);
                            assert_eq!(
                                got64, want64,
                                "packed f64 {m}x{k}x{n} {strategy:?} {par:?}"
                            );
                            let got32 = tiled::gemm_f32(&a32, &b32, m, k, n, strategy, &par);
                            assert_eq!(
                                got32, want32,
                                "packed f32 {m}x{k}x{n} {strategy:?} {par:?}"
                            );
                        }
                    }
                    let par = ParallelismConfig {
                        threads,
                        tiles,
                        micro: MicroConfig::DEFAULT,
                        split: RowSplit::Interleaved,
                        simd: SimdLevel::Scalar,
                    };
                    let unp64 =
                        tiled::gemm_unpacked_f64(a.data(), b.data(), m, k, n, strategy, &par);
                    assert_eq!(unp64, want64, "unpacked f64 {m}x{k}x{n} {strategy:?}");
                    let unp32 = tiled::gemm_unpacked_f32(&a32, &b32, m, k, n, strategy, &par);
                    assert_eq!(unp32, want32, "unpacked f32 {m}x{k}x{n} {strategy:?}");
                }
            }
        }
    }
}

#[test]
fn prop_generic_path_ragged_shapes() {
    // Same edge-case zoo for the blocked generic (software-precision)
    // path, against crate::gemm::generic_gemm.
    let shapes: &[(usize, usize, usize)] =
        &[(7, 61, 29), (1, 97, 33), (9, 0, 5), (3, 31, 3), (5, 129, 17)];
    let mut cases = Cases::new(0x6E171C);
    let d = Distribution::normal_1_1();
    for &(m, k, n) in shapes {
        for p in [Precision::Bf16, Precision::F16] {
            let a: Vec<f64> =
                Matrix::sample(m, k, &d, &mut cases.rng).data().iter().map(|&x| p.quantize(x)).collect();
            let b: Vec<f64> =
                Matrix::sample(k, n, &d, &mut cases.rng).data().iter().map(|&x| p.quantize(x)).collect();
            for strategy in
                [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
            {
                let want = generic_gemm(&a, &b, m, k, n, p, strategy);
                for threads in [1usize, 2, 8] {
                    for tiles in tile_grid() {
                        let par = ParallelismConfig::with_threads(threads).tiles(tiles);
                        let got = tiled::gemm_generic(&a, &b, m, k, n, p, strategy, &par);
                        assert_eq!(got, want, "generic {m}x{k}x{n} {p:?} {strategy:?} {par:?}");
                    }
                }
            }
        }
    }
}

#[test]
fn larger_shapes_cross_tile_boundaries() {
    // A few fixed shapes that are guaranteed to exercise multiple K-blocks,
    // multiple column blocks and uneven row panels at every thread count.
    let mut cases = Cases::new(0x5EED);
    let d = Distribution::uniform_pm1();
    for &(m, k, n) in &[(16usize, 130usize, 70usize), (7, 257, 33), (5, 64, 129)] {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for model in [
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::cpu(Precision::F64),
            AccumModel::wide(Precision::Bf16),
        ] {
            let (want_c, want_acc) = reference(model, &a, &b);
            for threads in [1usize, 2, 4] {
                for &simd in &SimdLevel::available_levels() {
                    let par = ParallelismConfig::with_threads(threads)
                        .tiles(TileConfig::new(4, 32, 24))
                        .micro(MicroConfig::new(4, 8))
                        .simd(simd);
                    let got = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                    let tag = format!("{model:?} t={threads} simd={}", simd.name());
                    assert_eq!(got.acc.data(), want_acc.as_slice(), "{tag}");
                    assert_eq!(got.c.data(), want_c.as_slice(), "{tag}");
                }
            }
        }
    }
}

#[test]
fn prop_fused_probe_equals_post_hoc_sweep() {
    // The fused-epilogue verify point: `matmul_mixed_fused` must leave
    // the GEMM output bitwise-untouched AND produce per-row checks
    // bitwise-identical to a post-hoc `fused_sweep` over the same
    // accumulator — across the ragged zoo (k = 0, single row/column,
    // n < NR, threads > m), all three strategies, the native f64/f32 and
    // generic soft-float dispatch paths, and every parallel config.
    let shapes: &[(usize, usize, usize)] = &[
        (7, 61, 93),
        (13, 257, 31),
        (1, 97, 257),
        (9, 0, 5),
        (3, 31, 3),
        (2, 16, 1),
        (5, 129, 17),
    ];
    let mut cases = Cases::new(0xF05ED);
    let levels = SimdLevel::available_levels();
    let d = Distribution::uniform_pm1();
    for (si, &(m, k, n)) in shapes.iter().enumerate() {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        let weights: Vec<f64> = (1..=n).map(|j| j as f64).collect();
        // Alternate tight/loose row thresholds so both flag outcomes occur.
        let thresholds: Vec<f64> = (0..m).map(|i| if i % 2 == 0 { 1e-12 } else { 1e3 }).collect();
        for (input, work, out) in [
            (Precision::F64, Precision::F64, Precision::F64),
            (Precision::F32, Precision::F32, Precision::F32),
            (Precision::Bf16, Precision::F32, Precision::Bf16),
            (Precision::Bf16, Precision::Bf16, Precision::Bf16),
        ] {
            for strategy in
                [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
            {
                let model = AccumModel { input, work, strategy, out };
                let (b_enc, wide) = if k == 0 {
                    // Zero-depth B never reaches the encoder; hand the
                    // engine an empty encoded operand directly.
                    (Matrix::zeros(0, n + 2), 2)
                } else {
                    let enc =
                        vabft::abft::ChecksumEncoding::encode_b_wide(&b, &GemmEngine::new(model));
                    let wide = enc.wide_cols();
                    (enc.b_encoded, wide)
                };
                let probe = FusedProbe { n, weights: &weights, thresholds: &thresholds };
                for threads in [1usize, 2, 8] {
                    for tiles in tile_grid() {
                        // Sweep every dispatchable SIMD level through the
                        // fused-epilogue kernels too: the epilogue reads
                        // rows straight out of the microkernel store, so a
                        // vector-width bug shows up here first.
                        for &simd in &levels {
                            let micro = micro_grid()[(si + threads) % micro_grid().len()];
                            let split = if threads % 2 == 0 {
                                RowSplit::Interleaved
                            } else {
                                RowSplit::Contiguous
                            };
                            let par = ParallelismConfig { threads, tiles, micro, split, simd };
                            let engine = GemmEngine::with_parallelism(model, par);
                            let (got, checks) =
                                engine.matmul_mixed_fused(&a, &b_enc, wide, &probe);
                            let plain = engine.matmul_mixed(&a, &b_enc, wide);
                            assert_eq!(
                                got.acc.data(),
                                plain.acc.data(),
                                "fused acc diverged {m}x{k}x{n} {model:?} {par:?}"
                            );
                            assert_eq!(
                                got.c.data(),
                                plain.c.data(),
                                "fused c diverged {m}x{k}x{n} {model:?} {par:?}"
                            );
                            assert_eq!(
                                checks,
                                engine.fused_sweep(&plain.acc, &probe),
                                "fused checks diverged {m}x{k}x{n} {model:?} {par:?}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_fused_policy_bitwise_equals_post_hoc_online() {
    // FtGemm under `VerifyPolicy::fused()` vs the default post-hoc
    // online policy: identical output bits and identical report
    // measurements (max |D1|, min threshold — down to the bit) on the
    // ragged zoo, at every precision triple, strategy and thread count.
    // Offline verification must also leave clean outputs bitwise-equal
    // (verification never touches a clean product).
    let shapes: &[(usize, usize, usize)] = &[
        (7, 61, 93),
        (13, 257, 31),
        (1, 97, 257),
        (9, 0, 5),
        (3, 31, 3),
        (2, 16, 1),
        (5, 129, 17),
    ];
    let triples = [
        (Precision::F64, Precision::F64, Precision::F64),
        (Precision::F32, Precision::F32, Precision::F32),
        (Precision::Bf16, Precision::F32, Precision::Bf16),
        (Precision::F16, Precision::F32, Precision::F16),
        (Precision::Bf16, Precision::Bf16, Precision::Bf16),
    ];
    let mut cases = Cases::new(0xF0011);
    let levels = SimdLevel::available_levels();
    let d = Distribution::normal_1_1();
    for (ci, &(m, k, n)) in shapes.iter().enumerate() {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for (pi, &(input, work, out)) in triples.iter().enumerate() {
            for (ti, &strategy) in
                [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
                    .iter()
                    .enumerate()
            {
                let model = AccumModel { input, work, strategy, out };
                for threads in [1usize, 2, 8] {
                    let tiles = tile_grid()[(ci + pi + ti + threads) % tile_grid().len()];
                    let micro = micro_grid()[(ci + threads) % micro_grid().len()];
                    let split = if (ci + threads) % 2 == 0 {
                        RowSplit::Contiguous
                    } else {
                        RowSplit::Interleaved
                    };
                    let simd = levels[(ci + pi + ti + threads) % levels.len()];
                    let par = ParallelismConfig { threads, tiles, micro, split, simd };
                    let mk = |policy| {
                        FtGemm::new(
                            GemmEngine::with_parallelism(model, par),
                            Box::new(VabftThreshold::default()),
                            policy,
                        )
                    };
                    let fused = mk(VerifyPolicy::fused()).multiply(&a, &b).unwrap();
                    let posthoc = mk(VerifyPolicy::default()).multiply(&a, &b).unwrap();
                    let offline = mk(VerifyPolicy::offline()).multiply(&a, &b).unwrap();
                    let tag = format!("{m}x{k}x{n} {model:?} {par:?}");
                    assert_eq!(fused.c.data(), posthoc.c.data(), "fused C diverged: {tag}");
                    assert_eq!(fused.report.verdict, posthoc.report.verdict, "{tag}");
                    assert_eq!(
                        fused.report.detections.len(),
                        posthoc.report.detections.len(),
                        "{tag}"
                    );
                    assert_eq!(fused.report.rows_checked, posthoc.report.rows_checked, "{tag}");
                    assert_eq!(
                        fused.report.max_abs_d1.to_bits(),
                        posthoc.report.max_abs_d1.to_bits(),
                        "{tag}"
                    );
                    assert_eq!(
                        fused.report.min_threshold.to_bits(),
                        posthoc.report.min_threshold.to_bits(),
                        "{tag}"
                    );
                    // The fused report says where detection ran; the
                    // post-hoc and offline reports say it didn't.
                    assert_eq!(fused.report.rows_fused, fused.report.rows_checked, "{tag}");
                    assert_eq!(posthoc.report.rows_fused, 0, "{tag}");
                    assert_eq!(offline.report.rows_fused, 0, "{tag}");
                    // Clean inputs: the verify point must not leak into
                    // the product at all.
                    assert_eq!(fused.c.data(), offline.c.data(), "offline C diverged: {tag}");
                }
            }
        }
    }
}

#[test]
fn fused_policy_injection_decisions_match_post_hoc() {
    // A simulated upset lands after the kernel returns; under the fused
    // policy the pipeline re-runs the epilogue's checks over the mutated
    // accumulator at the same verification point. Detections (row,
    // localized column, D1/D2/threshold bits), verdicts and repaired
    // outputs must all be bitwise-equal to the post-hoc online policy.
    let mut rng = Xoshiro256pp::seed_from_u64(0xFA57);
    let d = Distribution::normal_1_1();
    for model in [AccumModel::wide(Precision::Bf16), AccumModel::gpu_highprec(Precision::F32)] {
        let a = Matrix::sample(8, 64, &d, &mut rng);
        let b = Matrix::sample(64, 32, &d, &mut rng);
        let mk = |policy| {
            FtGemm::new(GemmEngine::new(model), Box::new(VabftThreshold::default()), policy)
        };
        let inject = |o: &mut GemmOutput| {
            let v = o.acc.get(3, 7);
            o.acc.set(3, 7, v + 4.0);
        };
        let fused = mk(VerifyPolicy::fused()).multiply_with_injection(&a, &b, inject).unwrap();
        let posthoc =
            mk(VerifyPolicy::default()).multiply_with_injection(&a, &b, inject).unwrap();
        assert_eq!(fused.report.verdict, Verdict::Corrected, "{model:?}");
        assert_eq!(posthoc.report.verdict, Verdict::Corrected, "{model:?}");
        assert_eq!(fused.c.data(), posthoc.c.data(), "repaired outputs must match bitwise");
        assert_eq!(fused.report.detections.len(), 1, "{model:?}");
        for (f, p) in fused.report.detections.iter().zip(&posthoc.report.detections) {
            assert_eq!((f.row, f.col), (p.row, p.col), "{model:?}");
            assert_eq!(f.d1.to_bits(), p.d1.to_bits(), "{model:?}");
            assert_eq!(f.d2.to_bits(), p.d2.to_bits(), "{model:?}");
            assert_eq!(f.threshold.to_bits(), p.threshold.to_bits(), "{model:?}");
        }
        assert_eq!(fused.report.rows_fused, fused.report.rows_checked);
        assert_eq!(posthoc.report.rows_fused, 0);
    }
}

#[test]
fn two_dimensional_encoding_is_schedule_preserving() {
    // Invariant #7: the A-side checksum rows ride the packed operand
    // exactly as the B-side checksum columns do — no data element's
    // rounding schedule may change under any encoding mode. Data rows of
    // `matmul_mixed_2d` must be bitwise-identical to the 1D encoded
    // multiply, the full 2D product (checksum rows included) must be
    // thread/tile/microkernel-invariant, and FtGemm's clean outputs must
    // be bitwise-equal across all three encoding modes.
    let mut rng = Xoshiro256pp::seed_from_u64(0x2D5C);
    let d = Distribution::normal_1_1();
    for model in [
        AccumModel::wide(Precision::Bf16),
        AccumModel::gpu_highprec(Precision::F32),
        AccumModel::cpu(Precision::F64),
    ] {
        let a = Matrix::sample(9, 80, &d, &mut rng);
        let b = Matrix::sample(80, 24, &d, &mut rng);
        let base_engine = GemmEngine::new(model);
        let enc = vabft::abft::ChecksumEncoding::encode_b_wide(&b, &base_engine);
        let cenc = vabft::abft::ColumnEncoding::encode_a_wide(&a, &base_engine);
        let plain = base_engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
        let base = base_engine.matmul_mixed_2d(
            &cenc.a_encoded,
            &enc.b_encoded,
            enc.wide_cols(),
            cenc.wide_rows(),
        );
        for i in 0..a.rows() {
            assert_eq!(base.acc.row(i), plain.acc.row(i), "{model:?}: acc row {i} diverged");
            assert_eq!(base.c.row(i), plain.c.row(i), "{model:?}: c row {i} diverged");
        }
        for threads in [2usize, 4] {
            for tiles in tile_grid() {
                for micro in [MicroConfig::DEFAULT, MicroConfig::new(3, 5)] {
                    for &simd in &SimdLevel::available_levels() {
                        let split = if threads == 2 {
                            RowSplit::Interleaved
                        } else {
                            RowSplit::Contiguous
                        };
                        let par = ParallelismConfig { threads, tiles, micro, split, simd };
                        let engine = GemmEngine::with_parallelism(model, par);
                        let got = engine.matmul_mixed_2d(
                            &cenc.a_encoded,
                            &enc.b_encoded,
                            enc.wide_cols(),
                            cenc.wide_rows(),
                        );
                        assert_eq!(got.acc.data(), base.acc.data(), "{model:?} {par:?}");
                        assert_eq!(got.c.data(), base.c.data(), "{model:?} {par:?}");
                    }
                }
            }
        }
        // Clean FtGemm outputs bitwise-equal across every encoding mode:
        // the geometry changes what verification *can repair*, never what
        // a clean multiply *produces*.
        let mk = |encoding| {
            FtGemm::new(
                GemmEngine::new(model),
                Box::new(VabftThreshold::default()),
                VerifyPolicy::default().with_encoding(encoding),
            )
        };
        let row_only = mk(EncodingMode::RowOnly).multiply(&a, &b).unwrap();
        for encoding in [EncodingMode::RowCol, EncodingMode::Grid] {
            let out = mk(encoding).multiply(&a, &b).unwrap();
            assert_eq!(out.report.verdict, Verdict::Clean, "{model:?} {encoding:?}");
            assert_eq!(
                out.c.data(),
                row_only.c.data(),
                "{model:?} {encoding:?}: clean output must not depend on encoding mode"
            );
        }
    }
}

#[test]
fn encoded_multiply_is_thread_invariant() {
    // The ABFT layer multiplies *encoded* operands via matmul_mixed with
    // wide checksum columns; that path must also be schedule-invariant.
    let mut rng = Xoshiro256pp::seed_from_u64(0xABF7);
    let d = Distribution::normal_1_1();
    let a = Matrix::sample(9, 80, &d, &mut rng);
    let b = Matrix::sample(80, 24, &d, &mut rng);
    let model = AccumModel::wide(Precision::Bf16);
    let base_engine = GemmEngine::new(model);
    let enc = vabft::abft::ChecksumEncoding::encode_b_wide(&b, &base_engine);
    let base = base_engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
    for threads in [2usize, 4] {
        for tiles in tile_grid() {
            for micro in [MicroConfig::DEFAULT, MicroConfig::new(3, 5)] {
                for &simd in &SimdLevel::available_levels() {
                    let split =
                        if threads == 2 { RowSplit::Interleaved } else { RowSplit::Contiguous };
                    let par = ParallelismConfig { threads, tiles, micro, split, simd };
                    let engine = GemmEngine::with_parallelism(model, par);
                    let got = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
                    assert_eq!(got.acc.data(), base.acc.data(), "{par:?}");
                    assert_eq!(got.c.data(), base.c.data(), "{par:?}");
                }
            }
        }
    }
}
