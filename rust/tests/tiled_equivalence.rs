//! Schedule-preservation property tests for the tiled parallel engine
//! (hand-rolled generators — the proptest crate is not in the offline
//! registry; failing cases print their full configuration).
//!
//! The invariant V-ABFT depends on: for randomized (m, k, n, seed,
//! AccumModel, tile sizes, thread counts 1/2/4), the tiled engine's output
//! **and** pre-quantization accumulator are *bitwise equal* to the naive
//! reference kernels, for all three `ReduceStrategy` variants. The
//! reference is computed here from `gemm::kernels` / `gemm::generic_gemm`
//! directly — independently of the engine's dispatch code — so a
//! regression in either layer trips the test.

use vabft::gemm::{
    generic_gemm, kernels, AccumModel, GemmEngine, ParallelismConfig, ReduceStrategy, TileConfig,
};
use vabft::prelude::*;

struct Cases {
    rng: Xoshiro256pp,
}

impl Cases {
    fn new(seed: u64) -> Cases {
        Cases { rng: Xoshiro256pp::seed_from_u64(seed) }
    }

    fn dims(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.uniform_u64((hi - lo + 1) as u64) as usize
    }

    /// (input, work, out) triples covering all three kernel dispatch
    /// paths: native f64, native f32, and the generic soft-float path.
    fn precisions(&mut self) -> (Precision, Precision, Precision) {
        match self.rng.uniform_u64(6) {
            0 => (Precision::F64, Precision::F64, Precision::F64),
            1 => (Precision::F32, Precision::F32, Precision::F32),
            2 => (Precision::Bf16, Precision::F32, Precision::Bf16), // wide
            3 => (Precision::F16, Precision::F32, Precision::F16),   // wide
            4 => (Precision::F8E4M3, Precision::F32, Precision::F16), // fp8
            _ => (Precision::Bf16, Precision::Bf16, Precision::Bf16), // generic
        }
    }
}

/// The naive reference: input quantization + reference kernel + one output
/// rounding, mirroring the engine contract without touching its dispatch.
fn reference(model: AccumModel, a: &Matrix, b: &Matrix) -> (Vec<f64>, Vec<f64>) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let aq: Vec<f64> = a.data().iter().map(|&x| model.input.quantize(x)).collect();
    let bq: Vec<f64> = b.data().iter().map(|&x| model.input.quantize(x)).collect();
    let acc: Vec<f64> = match model.work {
        Precision::F64 => kernels::reference_gemm_f64(&aq, &bq, m, k, n, model.strategy),
        Precision::F32 => {
            let a32: Vec<f32> = aq.iter().map(|&x| x as f32).collect();
            let b32: Vec<f32> = bq.iter().map(|&x| x as f32).collect();
            kernels::reference_gemm_f32(&a32, &b32, m, k, n, model.strategy)
                .into_iter()
                .map(|x| x as f64)
                .collect()
        }
        other => generic_gemm(&aq, &bq, m, k, n, other, model.strategy),
    };
    let c: Vec<f64> = if model.out != model.work {
        acc.iter().map(|&x| model.out.quantize(x)).collect()
    } else {
        acc.clone()
    };
    (c, acc)
}

fn tile_grid() -> Vec<TileConfig> {
    vec![
        TileConfig::DEFAULT,
        TileConfig::new(1, 3, 5),  // degenerate tiny tiles, odd K blocks
        TileConfig::new(2, 7, 13), // ragged everything
        TileConfig::new(8, 64, 16),
    ]
}

#[test]
fn prop_tiled_engine_bitwise_equals_naive_reference() {
    let mut cases = Cases::new(0x711ED);
    for case in 0..24 {
        let (m, k, n) = (cases.dims(1, 12), cases.dims(1, 48), cases.dims(1, 32));
        let (input, work, out) = cases.precisions();
        let d = Distribution::normal_1_1();
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let model = AccumModel { input, work, strategy, out };
            let (want_c, want_acc) = reference(model, &a, &b);
            for threads in [1usize, 2, 4] {
                for tiles in tile_grid() {
                    let par = ParallelismConfig { threads, tiles };
                    let got = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                    assert_eq!(
                        got.acc.data(),
                        want_acc.as_slice(),
                        "case {case}: acc diverged ({m}x{k}x{n}, {model:?}, {par:?})"
                    );
                    assert_eq!(
                        got.c.data(),
                        want_c.as_slice(),
                        "case {case}: c diverged ({m}x{k}x{n}, {model:?}, {par:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn larger_shapes_cross_tile_boundaries() {
    // A few fixed shapes that are guaranteed to exercise multiple K-blocks,
    // multiple column blocks and uneven row panels at every thread count.
    let mut cases = Cases::new(0x5EED);
    let d = Distribution::uniform_pm1();
    for &(m, k, n) in &[(16usize, 130usize, 70usize), (7, 257, 33), (5, 64, 129)] {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for model in [
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::cpu(Precision::F64),
            AccumModel::wide(Precision::Bf16),
        ] {
            let (want_c, want_acc) = reference(model, &a, &b);
            for threads in [1usize, 2, 4] {
                let par = ParallelismConfig::with_threads(threads)
                    .tiles(TileConfig::new(4, 32, 24));
                let got = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                assert_eq!(got.acc.data(), want_acc.as_slice(), "{model:?} t={threads}");
                assert_eq!(got.c.data(), want_c.as_slice(), "{model:?} t={threads}");
            }
        }
    }
}

#[test]
fn encoded_multiply_is_thread_invariant() {
    // The ABFT layer multiplies *encoded* operands via matmul_mixed with
    // wide checksum columns; that path must also be schedule-invariant.
    let mut rng = Xoshiro256pp::seed_from_u64(0xABF7);
    let d = Distribution::normal_1_1();
    let a = Matrix::sample(9, 80, &d, &mut rng);
    let b = Matrix::sample(80, 24, &d, &mut rng);
    let model = AccumModel::wide(Precision::Bf16);
    let base_engine = GemmEngine::new(model);
    let enc = vabft::abft::ChecksumEncoding::encode_b_wide(&b, &base_engine);
    let base = base_engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
    for threads in [2usize, 4] {
        for tiles in tile_grid() {
            let par = ParallelismConfig { threads, tiles };
            let engine = GemmEngine::with_parallelism(model, par);
            let got = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
            assert_eq!(got.acc.data(), base.acc.data(), "{par:?}");
            assert_eq!(got.c.data(), base.c.data(), "{par:?}");
        }
    }
}
