//! Schedule-preservation property tests for the tiled/packed parallel
//! engine (hand-rolled generators — the proptest crate is not in the
//! offline registry; failing cases print their full configuration).
//!
//! The invariant V-ABFT depends on: for randomized (m, k, n, seed,
//! AccumModel, tile sizes, microkernel shapes, thread counts 1/2/4), the
//! engine's output **and** pre-quantization accumulator are *bitwise
//! equal* to the naive reference kernels, for all three `ReduceStrategy`
//! variants. The reference is computed here from `gemm::kernels` /
//! `gemm::generic_gemm` directly — independently of the engine's dispatch
//! code — so a regression in either layer trips the test. The retained
//! PR-1 unpacked engine is cross-checked against the same reference,
//! giving two independent implementations that must agree with the
//! packed path everywhere.

use vabft::gemm::{
    generic_gemm, kernels, tiled, AccumModel, GemmEngine, MicroConfig, ParallelismConfig,
    ReduceStrategy, TileConfig,
};
use vabft::prelude::*;

struct Cases {
    rng: Xoshiro256pp,
}

impl Cases {
    fn new(seed: u64) -> Cases {
        Cases { rng: Xoshiro256pp::seed_from_u64(seed) }
    }

    fn dims(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.uniform_u64((hi - lo + 1) as u64) as usize
    }

    /// (input, work, out) triples covering all three kernel dispatch
    /// paths: native f64, native f32, and the generic soft-float path.
    fn precisions(&mut self) -> (Precision, Precision, Precision) {
        match self.rng.uniform_u64(6) {
            0 => (Precision::F64, Precision::F64, Precision::F64),
            1 => (Precision::F32, Precision::F32, Precision::F32),
            2 => (Precision::Bf16, Precision::F32, Precision::Bf16), // wide
            3 => (Precision::F16, Precision::F32, Precision::F16),   // wide
            4 => (Precision::F8E4M3, Precision::F32, Precision::F16), // fp8
            _ => (Precision::Bf16, Precision::Bf16, Precision::Bf16), // generic
        }
    }
}

/// The naive reference: input quantization + reference kernel + one output
/// rounding, mirroring the engine contract without touching its dispatch.
fn reference(model: AccumModel, a: &Matrix, b: &Matrix) -> (Vec<f64>, Vec<f64>) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let aq: Vec<f64> = a.data().iter().map(|&x| model.input.quantize(x)).collect();
    let bq: Vec<f64> = b.data().iter().map(|&x| model.input.quantize(x)).collect();
    let acc: Vec<f64> = match model.work {
        Precision::F64 => kernels::reference_gemm_f64(&aq, &bq, m, k, n, model.strategy),
        Precision::F32 => {
            let a32: Vec<f32> = aq.iter().map(|&x| x as f32).collect();
            let b32: Vec<f32> = bq.iter().map(|&x| x as f32).collect();
            kernels::reference_gemm_f32(&a32, &b32, m, k, n, model.strategy)
                .into_iter()
                .map(|x| x as f64)
                .collect()
        }
        other => generic_gemm(&aq, &bq, m, k, n, other, model.strategy),
    };
    let c: Vec<f64> = if model.out != model.work {
        acc.iter().map(|&x| model.out.quantize(x)).collect()
    } else {
        acc.clone()
    };
    (c, acc)
}

fn tile_grid() -> Vec<TileConfig> {
    vec![
        TileConfig::DEFAULT,
        TileConfig::new(1, 3, 5),  // degenerate tiny tiles, odd K blocks
        TileConfig::new(2, 7, 13), // ragged everything
        TileConfig::new(8, 64, 16),
    ]
}

fn micro_grid() -> Vec<MicroConfig> {
    vec![
        MicroConfig::DEFAULT,       // monomorphized 8x8
        MicroConfig::new(4, 8),     // monomorphized, asymmetric
        MicroConfig::new(1, 4),     // single-row panels
        MicroConfig::new(3, 5),     // dynamic-fallback kernel, coprime
        MicroConfig::new(16, 4),    // tall panels
    ]
}

#[test]
fn prop_tiled_engine_bitwise_equals_naive_reference() {
    let mut cases = Cases::new(0x711ED);
    for case in 0..24 {
        let (m, k, n) = (cases.dims(1, 12), cases.dims(1, 48), cases.dims(1, 32));
        let (input, work, out) = cases.precisions();
        let d = Distribution::normal_1_1();
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let model = AccumModel { input, work, strategy, out };
            let (want_c, want_acc) = reference(model, &a, &b);
            for threads in [1usize, 2, 4] {
                for tiles in tile_grid() {
                    let micro = micro_grid()[case % micro_grid().len()];
                    // Alternate the row-split policy across cases: both
                    // must be bitwise-equal to the reference.
                    let split = if case % 2 == 0 {
                        RowSplit::Contiguous
                    } else {
                        RowSplit::Interleaved
                    };
                    let par = ParallelismConfig { threads, tiles, micro, split };
                    let got = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                    assert_eq!(
                        got.acc.data(),
                        want_acc.as_slice(),
                        "case {case}: acc diverged ({m}x{k}x{n}, {model:?}, {par:?})"
                    );
                    assert_eq!(
                        got.c.data(),
                        want_c.as_slice(),
                        "case {case}: c diverged ({m}x{k}x{n}, {model:?}, {par:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_packed_path_ragged_shapes() {
    // The packed-path edge-case zoo: dimensions coprime with every
    // default block size (MR/NR/mc/kc/nc), k = 0, n smaller than NR,
    // single row, single column, more threads than rows. Packed AND
    // unpacked engines vs the reference kernels, bitwise, f32 + f64.
    let shapes: &[(usize, usize, usize)] = &[
        (7, 61, 93),   // coprime with 8/8/64/256/128
        (13, 257, 31), // k just past default kc, n < default nc
        (1, 97, 257),  // single row, n crosses nc
        (9, 0, 5),     // k = 0
        (3, 31, 3),    // n < NR
        (2, 16, 1),    // single column
        (5, 129, 17),  // threads (up to 8) > m
    ];
    let mut cases = Cases::new(0x4A66ED);
    let d = Distribution::uniform_pm1();
    for &(m, k, n) in shapes {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        let a32: Vec<f32> = a.data().iter().map(|&x| x as f32).collect();
        let b32: Vec<f32> = b.data().iter().map(|&x| x as f32).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want64 = kernels::reference_gemm_f64(a.data(), b.data(), m, k, n, strategy);
            let want32 = kernels::reference_gemm_f32(&a32, &b32, m, k, n, strategy);
            for threads in [1usize, 2, 8] {
                for tiles in tile_grid() {
                    for micro in micro_grid() {
                        let split = if threads % 2 == 0 {
                            RowSplit::Interleaved
                        } else {
                            RowSplit::Contiguous
                        };
                        let par = ParallelismConfig { threads, tiles, micro, split };
                        let got64 = tiled::gemm_f64(a.data(), b.data(), m, k, n, strategy, &par);
                        assert_eq!(
                            got64, want64,
                            "packed f64 {m}x{k}x{n} {strategy:?} {par:?}"
                        );
                        let got32 = tiled::gemm_f32(&a32, &b32, m, k, n, strategy, &par);
                        assert_eq!(
                            got32, want32,
                            "packed f32 {m}x{k}x{n} {strategy:?} {par:?}"
                        );
                    }
                    let par = ParallelismConfig {
                        threads,
                        tiles,
                        micro: MicroConfig::DEFAULT,
                        split: RowSplit::Interleaved,
                    };
                    let unp64 =
                        tiled::gemm_unpacked_f64(a.data(), b.data(), m, k, n, strategy, &par);
                    assert_eq!(unp64, want64, "unpacked f64 {m}x{k}x{n} {strategy:?}");
                    let unp32 = tiled::gemm_unpacked_f32(&a32, &b32, m, k, n, strategy, &par);
                    assert_eq!(unp32, want32, "unpacked f32 {m}x{k}x{n} {strategy:?}");
                }
            }
        }
    }
}

#[test]
fn prop_generic_path_ragged_shapes() {
    // Same edge-case zoo for the blocked generic (software-precision)
    // path, against crate::gemm::generic_gemm.
    let shapes: &[(usize, usize, usize)] =
        &[(7, 61, 29), (1, 97, 33), (9, 0, 5), (3, 31, 3), (5, 129, 17)];
    let mut cases = Cases::new(0x6E171C);
    let d = Distribution::normal_1_1();
    for &(m, k, n) in shapes {
        for p in [Precision::Bf16, Precision::F16] {
            let a: Vec<f64> =
                Matrix::sample(m, k, &d, &mut cases.rng).data().iter().map(|&x| p.quantize(x)).collect();
            let b: Vec<f64> =
                Matrix::sample(k, n, &d, &mut cases.rng).data().iter().map(|&x| p.quantize(x)).collect();
            for strategy in
                [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
            {
                let want = generic_gemm(&a, &b, m, k, n, p, strategy);
                for threads in [1usize, 2, 8] {
                    for tiles in tile_grid() {
                        let par = ParallelismConfig::with_threads(threads).tiles(tiles);
                        let got = tiled::gemm_generic(&a, &b, m, k, n, p, strategy, &par);
                        assert_eq!(got, want, "generic {m}x{k}x{n} {p:?} {strategy:?} {par:?}");
                    }
                }
            }
        }
    }
}

#[test]
fn larger_shapes_cross_tile_boundaries() {
    // A few fixed shapes that are guaranteed to exercise multiple K-blocks,
    // multiple column blocks and uneven row panels at every thread count.
    let mut cases = Cases::new(0x5EED);
    let d = Distribution::uniform_pm1();
    for &(m, k, n) in &[(16usize, 130usize, 70usize), (7, 257, 33), (5, 64, 129)] {
        let a = Matrix::sample(m, k, &d, &mut cases.rng);
        let b = Matrix::sample(k, n, &d, &mut cases.rng);
        for model in [
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::cpu(Precision::F64),
            AccumModel::wide(Precision::Bf16),
        ] {
            let (want_c, want_acc) = reference(model, &a, &b);
            for threads in [1usize, 2, 4] {
                let par = ParallelismConfig::with_threads(threads)
                    .tiles(TileConfig::new(4, 32, 24))
                    .micro(MicroConfig::new(4, 8));
                let got = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                assert_eq!(got.acc.data(), want_acc.as_slice(), "{model:?} t={threads}");
                assert_eq!(got.c.data(), want_c.as_slice(), "{model:?} t={threads}");
            }
        }
    }
}

#[test]
fn encoded_multiply_is_thread_invariant() {
    // The ABFT layer multiplies *encoded* operands via matmul_mixed with
    // wide checksum columns; that path must also be schedule-invariant.
    let mut rng = Xoshiro256pp::seed_from_u64(0xABF7);
    let d = Distribution::normal_1_1();
    let a = Matrix::sample(9, 80, &d, &mut rng);
    let b = Matrix::sample(80, 24, &d, &mut rng);
    let model = AccumModel::wide(Precision::Bf16);
    let base_engine = GemmEngine::new(model);
    let enc = vabft::abft::ChecksumEncoding::encode_b_wide(&b, &base_engine);
    let base = base_engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
    for threads in [2usize, 4] {
        for tiles in tile_grid() {
            for micro in [MicroConfig::DEFAULT, MicroConfig::new(3, 5)] {
                let split =
                    if threads == 2 { RowSplit::Interleaved } else { RowSplit::Contiguous };
                let par = ParallelismConfig { threads, tiles, micro, split };
                let engine = GemmEngine::with_parallelism(model, par);
                let got = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
                assert_eq!(got.acc.data(), base.acc.data(), "{par:?}");
                assert_eq!(got.c.data(), base.c.data(), "{par:?}");
            }
        }
    }
}
