//! SIMD dispatch matrix: every kernel level this host can execute must be
//! bitwise-identical to the scalar serial engine — across ragged shapes
//! (coprime dims, n smaller than NR, K = 0, more threads than rows),
//! both element types, all three reduction strategies, and both the
//! staged and the fused-epilogue paths. SIMD dispatch vectorizes only
//! across independent output columns, so this is the schedule-
//! preservation invariant extended to the instruction level.
//!
//! Also locks the tuning-manifest contract: a saved manifest round-trips
//! through [`vabft::gemm::EngineConfig`]'s shape-aware resolution, and a
//! corrupt or stale-schema manifest is rejected rather than silently
//! misconfiguring the engine.

use std::sync::Mutex;

use vabft::gemm::{
    tiled, EngineConfig, MicroConfig, ParallelismConfig, ReduceStrategy, RowSplit, SimdLevel,
    TileConfig,
};
use vabft::rng::{Rng, Xoshiro256pp};
use vabft::runtime::{TunedShape, TuningManifest};

/// Ragged shape zoo: coprime dims, n < every NR, k = 0, single row,
/// m smaller than any thread count under test.
const SHAPES: &[(usize, usize, usize)] =
    &[(7, 13, 5), (3, 31, 17), (5, 16, 3), (4, 0, 8), (1, 37, 23), (16, 24, 33)];

const STRATEGIES: [ReduceStrategy; 3] =
    [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise];

/// Small tiles so even tiny shapes cross block boundaries.
const TILES: TileConfig = TileConfig { mc: 8, kc: 16, nc: 8 };

const MICROS: [MicroConfig; 3] = [
    MicroConfig { mr: 8, nr: 8 },
    MicroConfig { mr: 4, nr: 16 },
    MicroConfig { mr: 2, nr: 8 },
];

fn scalar_par() -> ParallelismConfig {
    ParallelismConfig {
        threads: 1,
        tiles: TILES,
        micro: MicroConfig::DEFAULT,
        split: RowSplit::Contiguous,
        simd: SimdLevel::Scalar,
    }
}

fn fill_f32(len: usize, rng: &mut Xoshiro256pp) -> Vec<f32> {
    (0..len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

fn fill_f64(len: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

/// The full dispatch matrix for one element type, via the given runner.
fn sweep<T: Copy + PartialEq + std::fmt::Debug>(
    mut gemm: impl FnMut(usize, usize, usize, ReduceStrategy, &ParallelismConfig) -> Vec<T>,
) {
    let levels = SimdLevel::available_levels();
    assert!(levels.contains(&SimdLevel::Scalar));
    for &(m, k, n) in SHAPES {
        for strategy in STRATEGIES {
            let reference = gemm(m, k, n, strategy, &scalar_par());
            for &level in &levels {
                for threads in [1usize, 3] {
                    for micro in MICROS {
                        for split in [RowSplit::Contiguous, RowSplit::Interleaved] {
                            let par = ParallelismConfig {
                                threads,
                                tiles: TILES,
                                micro,
                                split,
                                simd: level,
                            };
                            let out = gemm(m, k, n, strategy, &par);
                            assert_eq!(
                                out, reference,
                                "divergence: {m}x{k}x{n} {strategy:?} level={} \
                                 threads={threads} micro={}x{} split={}",
                                level.name(),
                                micro.mr,
                                micro.nr,
                                split.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn dispatch_matrix_f32_staged() {
    // Operands derive deterministically from the shape so the reference
    // and every candidate see identical inputs.
    sweep(|m, k, n, strategy, par| {
        let mut sr = Xoshiro256pp::seed_from_u64((m * 73 + k * 31 + n) as u64);
        let a = fill_f32(m * k, &mut sr);
        let b = fill_f32(k * n, &mut sr);
        tiled::gemm_f32(&a, &b, m, k, n, strategy, par)
    });
}

#[test]
fn dispatch_matrix_f64_staged() {
    sweep(|m, k, n, strategy, par| {
        let mut sr = Xoshiro256pp::seed_from_u64((m * 73 + k * 31 + n) as u64 ^ 0xF64);
        let a = fill_f64(m * k, &mut sr);
        let b = fill_f64(k * n, &mut sr);
        tiled::gemm_f64(&a, &b, m, k, n, strategy, par)
    });
}

/// Fused-epilogue path: outputs AND the rows observed by the epilogue
/// (pre-store, in registers) must match the scalar engine bitwise at
/// every dispatch level.
#[test]
fn dispatch_matrix_f32_fused_epilogue() {
    sweep(|m, k, n, strategy, par| {
        let mut sr = Xoshiro256pp::seed_from_u64((m * 73 + k * 31 + n) as u64 ^ 0xF5D);
        let a = fill_f32(m * k, &mut sr);
        let b = fill_f32(k * n, &mut sr);
        let seen: Mutex<Vec<Vec<f32>>> = Mutex::new(vec![Vec::new(); m]);
        let c = tiled::gemm_f32_fused(&a, &b, m, k, n, strategy, par, &|i, row| {
            seen.lock().unwrap()[i] = row.to_vec();
        });
        // Fold the epilogue observations into the compared value so a
        // fused-path divergence is caught even if the stored C agrees.
        let mut out = c;
        for row in seen.into_inner().unwrap() {
            out.extend_from_slice(&row);
        }
        out
    });
}

#[test]
fn dispatch_matrix_f64_fused_epilogue() {
    sweep(|m, k, n, strategy, par| {
        let mut sr = Xoshiro256pp::seed_from_u64((m * 73 + k * 31 + n) as u64 ^ 0xFD64);
        let a = fill_f64(m * k, &mut sr);
        let b = fill_f64(k * n, &mut sr);
        let seen: Mutex<Vec<Vec<f64>>> = Mutex::new(vec![Vec::new(); m]);
        let c = tiled::gemm_f64_fused(&a, &b, m, k, n, strategy, par, &|i, row| {
            seen.lock().unwrap()[i] = row.to_vec();
        });
        let mut out = c;
        for row in seen.into_inner().unwrap() {
            out.extend_from_slice(&row);
        }
        out
    });
}

/// A forced level that this host cannot execute must fall back to scalar
/// (resolve(), not a crash or a wrong-bits kernel).
#[test]
fn unavailable_levels_resolve_to_scalar() {
    for level in [SimdLevel::Avx2, SimdLevel::Avx512, SimdLevel::Neon] {
        if !level.is_available() {
            assert_eq!(level.resolve(), SimdLevel::Scalar);
        }
    }
    assert_eq!(SimdLevel::Scalar.resolve(), SimdLevel::Scalar);
    assert_eq!(SimdLevel::Auto.resolve(), SimdLevel::detect());
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("vabft-simd-dispatch-{}-{name}.tsv", std::process::id()))
}

/// Save → load → shape-aware resolve: the tuned schedule for an exact
/// shape comes back field-for-field; an unrelated shape (beyond the
/// nearest-neighbour cap) resolves to the defaults; explicit builder
/// overrides always beat the manifest.
#[test]
fn manifest_round_trips_through_engine_config() {
    let mut manifest = TuningManifest::new("test-cpu");
    manifest.push(TunedShape {
        label: "gpt2/attn".into(),
        m: 8,
        k: 96,
        n: 32,
        tiles: TileConfig { mc: 32, kc: 48, nc: 16 },
        micro: MicroConfig { mr: 4, nr: 16 },
        threads: 2,
        split: RowSplit::Interleaved,
        simd: SimdLevel::Scalar,
        gflops: 12.375,
        baseline_gflops: 10.0625,
    });
    let path = tmp("roundtrip");
    manifest.save(&path).unwrap();
    let loaded = TuningManifest::load(&path).unwrap();
    assert_eq!(loaded, manifest);

    let cfg = EngineConfig::new().manifest(loaded);
    let tuned = cfg.resolve_for(8, 96, 32);
    assert_eq!(tuned.tiles, TileConfig { mc: 32, kc: 48, nc: 16 });
    assert_eq!(tuned.micro, MicroConfig { mr: 4, nr: 16 });
    assert_eq!(tuned.threads, 2);
    assert_eq!(tuned.split, RowSplit::Interleaved);
    assert_eq!(tuned.simd, SimdLevel::Scalar);

    // Far-away shape: beyond the lookup cap, nothing is filled in.
    let far = cfg.resolve_for(4096, 1, 4096);
    assert_eq!(far, ParallelismConfig::serial());

    // Explicit builder overrides beat the manifest at the tuned shape.
    let pinned = cfg.clone().threads(5).micro(8, 8).resolve_for(8, 96, 32);
    assert_eq!(pinned.threads, 5);
    assert_eq!(pinned.micro, MicroConfig::DEFAULT);
    assert_eq!(pinned.tiles, TileConfig { mc: 32, kc: 48, nc: 16 });

    std::fs::remove_file(&path).ok();
}

/// Corrupt or stale-schema manifests must be load errors, never a
/// silently misconfigured engine.
#[test]
fn corrupt_and_stale_manifests_are_rejected() {
    let stale = tmp("stale");
    std::fs::write(&stale, "schema\tvabft-tuning/v0\ncpu\tx\n").unwrap();
    assert!(TuningManifest::load(&stale).is_err(), "stale schema must be rejected");

    let corrupt = tmp("corrupt");
    std::fs::write(
        &corrupt,
        "schema\tvabft-tuning/v1\ncpu\tx\nshape\tlabel=a\tm=8\tk=not-a-number\tn=4\n",
    )
    .unwrap();
    assert!(TuningManifest::load(&corrupt).is_err(), "corrupt record must be rejected");

    let truncated = tmp("truncated");
    std::fs::write(&truncated, "").unwrap();
    assert!(TuningManifest::load(&truncated).is_err(), "empty file must be rejected");

    for p in [stale, corrupt, truncated] {
        std::fs::remove_file(&p).ok();
    }
}
