//! Coordinator concurrency stress: N submitter threads pushing mixed
//! clean/injected batched requests through `submit_batch` simultaneously,
//! against a small bounded queue (real backpressure). Asserts:
//!
//! * every response reaches the receiver tagged with its own request id,
//!   and carries the verdict its request implies (clean ↔ Clean,
//!   exponent-flip injected ↔ not Clean);
//! * metrics counters add up exactly across all threads and batches;
//! * `shutdown` drains queued work without deadlock (responses submitted
//!   before shutdown are all eventually delivered).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use vabft::coordinator::{Coordinator, CoordinatorConfig, GemmRequest, InjectSpec};
use vabft::prelude::*;

const WEIGHT_K: usize = 96;
const WEIGHT_N: usize = 48;
const SUBMITTERS: usize = 4;
const BATCHES_PER_THREAD: usize = 3;
const BATCH: usize = 8;

fn start() -> Coordinator {
    let cfg = CoordinatorConfig {
        workers: 4,
        queue_depth: 8, // smaller than the in-flight total: exercises backpressure
        model: AccumModel::wide(Precision::Bf16),
        ..Default::default()
    };
    let c = Coordinator::start(cfg);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let b = Matrix::sample_in(
        WEIGHT_K,
        WEIGHT_N,
        &Distribution::normal_1_1(),
        Precision::Bf16,
        &mut rng,
    );
    c.register_weight(7, &b);
    c
}

fn activation(seed: u64) -> Matrix {
    let mut rng = Xoshiro256pp::from_stream(0xAC7, seed);
    Matrix::sample_in(8, WEIGHT_K, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

/// Deterministically: every 4th request of a batch carries an injection.
fn is_faulty(idx: usize) -> bool {
    idx % 4 == 3
}

#[test]
fn concurrent_batched_submitters_route_and_count_exactly() {
    let c = start();
    let injected_total = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|s| {
        for tid in 0..SUBMITTERS {
            let c = &c;
            let injected_total = Arc::clone(&injected_total);
            s.spawn(move || {
                for batch in 0..BATCHES_PER_THREAD {
                    let reqs: Vec<GemmRequest> = (0..BATCH)
                        .map(|i| {
                            let seed = ((tid * BATCHES_PER_THREAD + batch) * BATCH + i) as u64;
                            let inject = if is_faulty(i) {
                                injected_total.fetch_add(1, Ordering::Relaxed);
                                Some(InjectSpec::output(
                                    i % 8,
                                    (5 * i) % WEIGHT_N,
                                    25, // f32 exponent bit (online grid)
                                ))
                            } else {
                                None
                            };
                            GemmRequest { a: activation(seed), weight: 7, inject }
                        })
                        .collect();
                    let pending = c.submit_batch(reqs);
                    assert_eq!(pending.len(), BATCH);
                    for (i, (id, rx)) in pending.into_iter().enumerate() {
                        let resp = rx.recv().expect("worker dropped reply");
                        assert_eq!(resp.id, id, "response mis-routed (thread {tid})");
                        let out = resp.result.expect("request failed");
                        if is_faulty(i) {
                            assert_ne!(
                                out.report.verdict,
                                Verdict::Clean,
                                "thread {tid} batch {batch} req {i}: fault missed"
                            );
                        } else {
                            assert_eq!(
                                out.report.verdict,
                                Verdict::Clean,
                                "thread {tid} batch {batch} req {i}: false alarm"
                            );
                        }
                    }
                }
            });
        }
    });

    let total = (SUBMITTERS * BATCHES_PER_THREAD * BATCH) as u64;
    let m = c.metrics();
    assert_eq!(m.jobs_submitted.get(), total);
    assert_eq!(m.jobs_completed.get(), total);
    assert_eq!(m.batches_submitted.get(), (SUBMITTERS * BATCHES_PER_THREAD) as u64);
    assert_eq!(m.latency.count(), total);
    let injected = injected_total.load(Ordering::Relaxed) as u64;
    assert!(injected > 0);
    assert!(
        m.faults_detected.get() >= injected,
        "detected {} < injected {injected}",
        m.faults_detected.get()
    );
    c.shutdown();
}

#[test]
fn shutdown_drains_pending_batch_without_deadlock() {
    let c = start();
    let reqs: Vec<GemmRequest> =
        (0..6).map(|i| GemmRequest { a: activation(900 + i), weight: 7, inject: None }).collect();
    let pending = c.submit_batch(reqs);
    c.shutdown(); // must not deadlock; queued jobs complete first
    for (id, rx) in pending {
        let resp = rx.recv().expect("response lost during shutdown");
        assert_eq!(resp.id, id);
        assert!(resp.result.is_ok());
    }
}
