//! Coordinator concurrency stress: N submitter threads pushing mixed
//! clean/injected batched requests through `submit_batch` simultaneously,
//! against a small bounded queue (real backpressure). Asserts:
//!
//! * every response reaches the receiver tagged with its own request id,
//!   and carries the verdict its request implies (clean ↔ Clean,
//!   exponent-flip injected ↔ not Clean);
//! * metrics counters add up exactly across all threads and batches —
//!   read through `ServiceMetrics::snapshot()`, the quiesced consistent
//!   cut (field-by-field reads can tear mid-drain);
//! * `shutdown` drains queued work without deadlock (responses submitted
//!   before shutdown are all eventually delivered), with and without
//!   cross-shard work stealing;
//! * a skewed shape mix (90% tiny GEMMs, 10% large) across shards with
//!   stealing enabled starves no submitter.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use vabft::coordinator::{
    Coordinator, CoordinatorConfig, GemmRequest, InjectSpec, PartitionPolicy, TopologyConfig,
};
use vabft::prelude::*;

const WEIGHT_K: usize = 96;
const WEIGHT_N: usize = 48;
const SUBMITTERS: usize = 4;
const BATCHES_PER_THREAD: usize = 3;
const BATCH: usize = 8;

fn start() -> Coordinator {
    let cfg = CoordinatorConfig {
        workers: 4,
        queue_depth: 8, // smaller than the in-flight total: exercises backpressure
        model: AccumModel::wide(Precision::Bf16),
        ..Default::default()
    };
    let c = Coordinator::start(cfg);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let b = Matrix::sample_in(
        WEIGHT_K,
        WEIGHT_N,
        &Distribution::normal_1_1(),
        Precision::Bf16,
        &mut rng,
    );
    c.register_weight(7, &b);
    c
}

fn activation(seed: u64) -> Matrix {
    let mut rng = Xoshiro256pp::from_stream(0xAC7, seed);
    Matrix::sample_in(8, WEIGHT_K, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

/// Deterministically: every 4th request of a batch carries an injection.
fn is_faulty(idx: usize) -> bool {
    idx % 4 == 3
}

#[test]
fn concurrent_batched_submitters_route_and_count_exactly() {
    let c = start();
    let injected_total = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|s| {
        for tid in 0..SUBMITTERS {
            let c = &c;
            let injected_total = Arc::clone(&injected_total);
            s.spawn(move || {
                for batch in 0..BATCHES_PER_THREAD {
                    let reqs: Vec<GemmRequest> = (0..BATCH)
                        .map(|i| {
                            let seed = ((tid * BATCHES_PER_THREAD + batch) * BATCH + i) as u64;
                            let inject = if is_faulty(i) {
                                injected_total.fetch_add(1, Ordering::Relaxed);
                                Some(InjectSpec::output(
                                    i % 8,
                                    (5 * i) % WEIGHT_N,
                                    25, // f32 exponent bit (online grid)
                                ))
                            } else {
                                None
                            };
                            GemmRequest { a: activation(seed), weight: 7, inject }
                        })
                        .collect();
                    let pending = c.submit_batch(reqs);
                    assert_eq!(pending.len(), BATCH);
                    for (i, (id, rx)) in pending.into_iter().enumerate() {
                        let resp = rx.recv().expect("worker dropped reply");
                        assert_eq!(resp.id, id, "response mis-routed (thread {tid})");
                        let out = resp.result.expect("request failed");
                        if is_faulty(i) {
                            assert_ne!(
                                out.report.verdict,
                                Verdict::Clean,
                                "thread {tid} batch {batch} req {i}: fault missed"
                            );
                        } else {
                            assert_eq!(
                                out.report.verdict,
                                Verdict::Clean,
                                "thread {tid} batch {batch} req {i}: false alarm"
                            );
                        }
                    }
                }
            });
        }
    });

    let total = (SUBMITTERS * BATCHES_PER_THREAD * BATCH) as u64;
    // Quiesced snapshot: one consistent cut across every counter (naive
    // per-field reads can observe torn totals mid-drain).
    let m = c.metrics().snapshot();
    assert_eq!(m.jobs_submitted, total);
    assert_eq!(m.jobs_completed, total);
    assert_eq!(m.batches_submitted, (SUBMITTERS * BATCHES_PER_THREAD) as u64);
    assert_eq!(m.latency_count, total);
    let injected = injected_total.load(Ordering::Relaxed) as u64;
    assert!(injected > 0);
    assert!(
        m.faults_detected >= injected,
        "detected {} < injected {injected}",
        m.faults_detected
    );
    c.shutdown();
}

/// `inconsistent_localizations` and `faults_corrected_grid` flow through
/// the quiesced snapshot exactly like `jobs_shed` — one consistent cut,
/// no torn reads. A checksum-entry flip is deterministic fuel: integer
/// operands make every sum exact in the work grid, so D2 is exactly zero
/// while D1 carries the flip — the ratio falls outside [1, N] and
/// localization is inconsistent in every trial. The row-only policy
/// burns a recompute; the grid policy's column code certifies the data
/// intact (all column syndromes exactly zero) and repairs without
/// recomputing.
#[test]
fn inconsistent_localization_counters_flow_through_snapshot() {
    const REQS: usize = 6;
    for (policy, expect) in [
        (VerifyPolicy::default(), Verdict::Recomputed),
        (VerifyPolicy::grid(), Verdict::CorrectedGrid),
    ] {
        let c = Coordinator::start(CoordinatorConfig {
            workers: 4,
            model: AccumModel::wide(Precision::Bf16),
            policy,
            ..Default::default()
        });
        let b = Matrix::from_fn(WEIGHT_K, WEIGHT_N, |i, j| ((i + 2 * j) % 3 + 1) as f64);
        c.register_weight(9, &b);
        let a = Matrix::from_fn(8, WEIGHT_K, |i, j| ((2 * i + j) % 3 + 1) as f64);
        let clean = c
            .call(GemmRequest { a: a.clone(), weight: 9, inject: None })
            .result
            .expect("clean run failed");
        assert_eq!(clean.report.verdict, Verdict::Clean);
        let reqs: Vec<GemmRequest> = (0..REQS)
            .map(|i| GemmRequest {
                a: a.clone(),
                weight: 9,
                inject: Some(InjectSpec::checksum(i % 8, 25)),
            })
            .collect();
        for (id, rx) in c.submit_batch(reqs) {
            let resp = rx.recv().expect("worker dropped reply");
            assert_eq!(resp.id, id);
            let out = resp.result.expect("request failed");
            assert_eq!(out.report.verdict, expect, "policy {:?}", policy.encoding);
            assert_eq!(out.report.inconsistent_localizations, 1);
            assert_eq!(
                out.c.data(),
                clean.c.data(),
                "a checksum fault never touches data: output must match the clean run"
            );
        }
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs_completed, (REQS + 1) as u64);
        assert_eq!(m.inconsistent_localizations, REQS as u64);
        if expect == Verdict::CorrectedGrid {
            assert_eq!(m.faults_corrected_grid, REQS as u64);
            assert_eq!(m.rows_recomputed, 0);
        } else {
            assert_eq!(m.faults_corrected_grid, 0);
            assert_eq!(m.rows_recomputed, REQS as u64);
        }
        c.shutdown();
    }
}

#[test]
fn shutdown_drains_pending_batch_without_deadlock() {
    let c = start();
    let reqs: Vec<GemmRequest> =
        (0..6).map(|i| GemmRequest { a: activation(900 + i), weight: 7, inject: None }).collect();
    let pending = c.submit_batch(reqs);
    c.shutdown(); // must not deadlock; queued jobs complete first
    for (id, rx) in pending {
        let resp = rx.recv().expect("response lost during shutdown");
        assert_eq!(resp.id, id);
        assert!(resp.result.is_ok());
    }
}

// ---------------------------------------------------------------------
// Sharded + work-stealing stress
// ---------------------------------------------------------------------

const TINY_K: usize = 24;
const TINY_N: usize = 16;
const BIG_K: usize = 160;
const BIG_N: usize = 128;
const TINY_WEIGHT: u32 = 1;
const BIG_WEIGHT: u32 = 2;

fn start_sharded(shards: usize, steal: bool, queue_depth: usize) -> Coordinator {
    let c = Coordinator::start(CoordinatorConfig {
        workers: 1, // one worker per shard: stealing is the only slack
        shards,
        steal,
        queue_depth,
        partition: PartitionPolicy::Interleaved,
        topology: Some(TopologyConfig::uniform(2, 2)),
        model: AccumModel::wide(Precision::Bf16),
        ..Default::default()
    });
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let tiny =
        Matrix::sample_in(TINY_K, TINY_N, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
    let big =
        Matrix::sample_in(BIG_K, BIG_N, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
    c.register_weight(TINY_WEIGHT, &tiny);
    c.register_weight(BIG_WEIGHT, &big);
    c
}

fn act_for(seed: u64, big: bool) -> Matrix {
    let mut rng = Xoshiro256pp::from_stream(0x51A7, seed);
    let (m, k) = if big { (96, BIG_K) } else { (4, TINY_K) };
    Matrix::sample_in(m, k, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

/// The skewed-mix soak: 90% tiny + 10% large requests from concurrent
/// submitters over 4 shards with one worker each and stealing on. Every
/// submitter must complete (no starvation behind the large GEMMs), every
/// response must carry its own id, and the quiesced totals must add up.
#[test]
fn work_stealing_soak_skewed_mix_completes_without_starvation() {
    const SOAK_SUBMITTERS: usize = 4;
    const SOAK_BATCHES: usize = 2;
    const SOAK_BATCH: usize = 10; // request i is big when i % 10 == 9

    let c = start_sharded(4, true, 4);
    std::thread::scope(|s| {
        for tid in 0..SOAK_SUBMITTERS {
            let c = &c;
            s.spawn(move || {
                for batch in 0..SOAK_BATCHES {
                    let reqs: Vec<GemmRequest> = (0..SOAK_BATCH)
                        .map(|i| {
                            let big = i % 10 == 9;
                            let seed = ((tid * SOAK_BATCHES + batch) * SOAK_BATCH + i) as u64;
                            GemmRequest {
                                a: act_for(seed, big),
                                weight: if big { BIG_WEIGHT } else { TINY_WEIGHT },
                                inject: None,
                            }
                        })
                        .collect();
                    for (id, rx) in c.submit_batch(reqs) {
                        let resp = rx.recv().expect("starved: response never arrived");
                        assert_eq!(resp.id, id, "response mis-routed (thread {tid})");
                        let out = resp.result.expect("request failed");
                        assert_eq!(out.report.verdict, Verdict::Clean);
                    }
                }
            });
        }
    });
    let total = (SOAK_SUBMITTERS * SOAK_BATCHES * SOAK_BATCH) as u64;
    let m = c.metrics().snapshot();
    assert_eq!(m.jobs_submitted, total);
    assert_eq!(m.jobs_completed, total);
    assert_eq!(m.batches_submitted, (SOAK_SUBMITTERS * SOAK_BATCHES) as u64);
    assert_eq!(m.latency_count, total);
    println!("soak: {} of {total} jobs were stolen cross-shard", m.jobs_stolen);
    c.shutdown();
}

/// Targeted steal scenario: pin shard 1's worker on one very large GEMM,
/// give shard 0 a small one, then queue tiny requests on both shards.
/// Shard 0's worker drains its own queue fast and must then steal shard
/// 1's backlog instead of idling — the large job is hundreds of times
/// the total tiny work, so a zero steal count means the steal path never
/// engaged.
#[test]
fn idle_shard_steals_busy_shards_backlog() {
    let c = start_sharded(2, true, 32);
    // id 0 → shard 0 (small big-ish job), id 1 → shard 1 (very large).
    let first =
        c.submit(GemmRequest { a: act_for(1000, false), weight: TINY_WEIGHT, inject: None });
    let mut rng = Xoshiro256pp::from_stream(0xB16, 0);
    // ~79 MFLOP: pins shard 1's worker for many steal-poll intervals
    // while its queue holds the tiny backlog.
    let huge =
        Matrix::sample_in(1920, BIG_K, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
    let second = c.submit(GemmRequest { a: huge, weight: BIG_WEIGHT, inject: None });
    // ids 2..42 alternate between the shards; shard 1's share queues up
    // behind the large job.
    let tiny: Vec<GemmRequest> = (0..40u64)
        .map(|i| GemmRequest { a: act_for(1100 + i, false), weight: TINY_WEIGHT, inject: None })
        .collect();
    let pending = c.submit_batch(tiny);
    for (id, rx) in pending {
        let resp = rx.recv().expect("tiny request starved");
        assert_eq!(resp.id, id);
        assert!(resp.result.is_ok());
    }
    assert!(first.recv().unwrap().result.is_ok());
    assert!(second.recv().unwrap().result.is_ok());
    let m = c.metrics().snapshot();
    assert_eq!(m.jobs_completed, 42);
    assert!(
        m.jobs_stolen >= 1,
        "no cross-shard steal despite a pinned shard with queued backlog"
    );
    c.shutdown();
}

/// Drain-on-shutdown under steal: requests queued across shards at
/// shutdown time are all still delivered (each shard's own workers drain
/// their queue; stealers sweep what they can), with no deadlock.
#[test]
fn shutdown_drains_across_shards_under_steal() {
    let c = start_sharded(4, true, 8);
    let reqs: Vec<GemmRequest> = (0..16)
        .map(|i| GemmRequest {
            a: act_for(2000 + i, i % 10 == 9),
            weight: if i % 10 == 9 { BIG_WEIGHT } else { TINY_WEIGHT },
            inject: None,
        })
        .collect();
    let pending = c.submit_batch(reqs);
    c.shutdown(); // must not deadlock; queued jobs complete first
    for (id, rx) in pending {
        let resp = rx.recv().expect("response lost during sharded shutdown");
        assert_eq!(resp.id, id);
        assert!(resp.result.is_ok());
    }
}
