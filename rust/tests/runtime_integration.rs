//! Integration tests over the AOT artifacts: the python-compiled L1/L2
//! HLO modules executed through the Rust PJRT runtime.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! vacuously, with a note on stderr) when the artifact directory is
//! missing so `cargo test` stays green on a fresh checkout.

use vabft::rng::{Rng, Xoshiro256pp};
use vabft::runtime::{artifacts_dir, literal_f32, literal_i32, PjrtRuntime};
use vabft::train::{StepFault, SyntheticCorpus, Trainer, TrainerConfig};

fn runtime_or_skip() -> Option<PjrtRuntime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!(
            "skipping: no artifacts at {} (run `make artifacts`)",
            dir.display()
        );
        return None;
    }
    Some(PjrtRuntime::from_artifacts(&dir).expect("artifacts load"))
}

fn rand_f32(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| (rng.standard_normal() as f32) * scale).collect()
}

#[test]
fn ftgemm_artifact_clean_run_verifies() {
    let Some(rt) = runtime_or_skip() else { return };
    let e = rt.manifest().get("ftgemm_f32").expect("manifest entry").clone();
    let (m, k, n) = (
        e.meta_parse::<usize>("m").unwrap(),
        e.meta_parse::<usize>("k").unwrap(),
        e.meta_parse::<usize>("n").unwrap(),
    );
    let a = rand_f32(m * k, 1, 1.0);
    let b = rand_f32(k * n, 2, 1.0);
    let fault = [-1.0f32, -1.0, 0.0, 0.0];
    let outs = rt
        .execute_f32(
            "ftgemm_f32",
            &[
                (&a, &[m as i64, k as i64]),
                (&b, &[k as i64, n as i64]),
                (&fault, &[4]),
            ],
        )
        .expect("execute");
    // outputs: c [m,n], ratio [m], d1 [m], loc [m]
    assert_eq!(outs[0].len(), m * n);
    assert_eq!(outs[1].len(), m);
    let max_ratio = outs[1].iter().cloned().fold(0.0f32, f32::max);
    assert!(max_ratio < 1.0, "clean run flagged: max ratio {max_ratio}");
    // numerics: spot check C[0][0] against an f64 dot
    let c00: f64 = (0..k).map(|kk| a[kk] as f64 * b[kk * n] as f64).sum();
    assert!(
        (outs[0][0] as f64 - c00).abs() < 1e-3,
        "C[0][0] {} vs {}",
        outs[0][0],
        c00
    );
}

#[test]
fn ftgemm_artifact_detects_and_localizes_fault() {
    let Some(rt) = runtime_or_skip() else { return };
    let e = rt.manifest().get("ftgemm_f32").unwrap().clone();
    let (m, k, n) = (
        e.meta_parse::<usize>("m").unwrap(),
        e.meta_parse::<usize>("k").unwrap(),
        e.meta_parse::<usize>("n").unwrap(),
    );
    let a = rand_f32(m * k, 3, 1.0);
    let b = rand_f32(k * n, 4, 1.0);
    let (frow, fcol, fdelta) = (7usize, 11usize, 25.0f32);
    let fault = [frow as f32, fcol as f32, fdelta, 1.0];
    let outs = rt
        .execute_f32(
            "ftgemm_f32",
            &[
                (&a, &[m as i64, k as i64]),
                (&b, &[k as i64, n as i64]),
                (&fault, &[4]),
            ],
        )
        .unwrap();
    let ratio = &outs[1];
    let d1 = &outs[2];
    let loc = &outs[3];
    assert!(ratio[frow] > 1.0, "fault not detected: ratio {}", ratio[frow]);
    assert!((d1[frow] - fdelta).abs() < 0.1, "d1 {} vs {}", d1[frow], fdelta);
    assert_eq!(loc[frow] as i64, fcol as i64, "localization failed");
    // other rows stay clean
    for (i, &r) in ratio.iter().enumerate() {
        if i != frow {
            assert!(r < 1.0, "row {i} falsely flagged ({r})");
        }
    }
}

#[test]
fn ftgemm_correct_artifact_repairs_in_kernel() {
    let Some(rt) = runtime_or_skip() else { return };
    let e = rt.manifest().get("ftgemm_f32_correct").unwrap().clone();
    let (m, k, n) = (
        e.meta_parse::<usize>("m").unwrap(),
        e.meta_parse::<usize>("k").unwrap(),
        e.meta_parse::<usize>("n").unwrap(),
    );
    let a = rand_f32(m * k, 5, 1.0);
    let b = rand_f32(k * n, 6, 1.0);
    let clean_fault = [-1.0f32, -1.0, 0.0, 0.0];
    let dims: [&[i64]; 3] = [&[m as i64, k as i64], &[k as i64, n as i64], &[4]];
    let clean = rt
        .execute_f32(
            "ftgemm_f32_correct",
            &[(&a, dims[0]), (&b, dims[1]), (&clean_fault, dims[2])],
        )
        .unwrap();
    let fault = [3.0f32, 9.0, -40.0, 1.0];
    let fixed = rt
        .execute_f32(
            "ftgemm_f32_correct",
            &[(&a, dims[0]), (&b, dims[1]), (&fault, dims[2])],
        )
        .unwrap();
    // In-kernel correction: output C matches the clean run everywhere.
    let mut worst = 0.0f32;
    for (x, y) in clean[0].iter().zip(&fixed[0]) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < 1e-2, "corrected output differs by {worst}");
    // and the fault was seen (ratio > 1 for row 3)
    assert!(fixed[1][3] > 1.0);
}

#[test]
fn train_step_artifact_loss_decreases_and_detects_faults() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = TrainerConfig::default();
    let mut trainer = Trainer::new(&rt, cfg).expect("trainer setup");
    let (b, s) = trainer.batch_dims();
    let mut corpus = SyntheticCorpus::new(256, 9);

    // a few clean steps: loss must drop from the ~ln(256)=5.55 start
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..8 {
        let toks = corpus.batch(b, s + 1);
        let out = trainer.step(&toks, None).expect("step");
        assert!(out.ratio < 1.0, "clean step flagged ({})", out.ratio);
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    assert!(
        last < first.unwrap(),
        "loss should decrease: {} -> {last}",
        first.unwrap()
    );

    // a faulted step must be detected and retried
    let toks = corpus.batch(b, s + 1);
    let out = trainer
        .step(
            &toks,
            Some(StepFault { gemm_index: 2, row: 17, col: 3, delta: 300.0 }),
        )
        .expect("faulted step");
    assert!(out.ratio > 1.0, "fault missed (ratio {})", out.ratio);
    assert!(out.retried, "supervisor should have re-executed");
    assert!(out.applied);
    assert_eq!(trainer.detections, 1);
}

#[test]
fn model_fwd_artifact_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let e = rt.manifest().get("model_fwd").unwrap().clone();
    let n_params: usize = e.meta_parse("n_params").unwrap();
    let batch = e.meta_dims("batch").unwrap();
    let vocab: usize = e.meta_parse("vocab").unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(11);

    let mut literals = Vec::new();
    for i in 0..n_params {
        let dims: Vec<i64> = e
            .meta_dims(&format!("param{i}"))
            .unwrap()
            .into_iter()
            .map(|d| d as i64)
            .collect();
        let n: i64 = dims.iter().product();
        let data: Vec<f32> = (0..n)
            .map(|_| (rng.standard_normal() * 0.05) as f32)
            .collect();
        literals.push(literal_f32(&data, &dims).unwrap());
    }
    let toks: Vec<i32> = (0..batch[0] * batch[1])
        .map(|_| rng.uniform_u64(vocab as u64) as i32)
        .collect();
    literals.push(literal_i32(&toks, &[batch[0] as i64, batch[1] as i64]).unwrap());
    literals.push(literal_f32(&[-1.0, 0.0, 0.0, 0.0], &[4]).unwrap());

    let outs = rt.execute("model_fwd", &literals).expect("model_fwd");
    assert_eq!(outs.len(), 2); // logits, ratio
    let logits = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), batch[0] * batch[1] * vocab);
    let ratio = outs[1].to_vec::<f32>().unwrap()[0];
    assert!(ratio < 1.0, "clean forward flagged ({ratio})");
}
