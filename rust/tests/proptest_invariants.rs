//! Property-based tests over randomized inputs (hand-rolled generators —
//! the proptest crate is not in the offline registry; same idea: many
//! random cases per invariant, with the failing seed printed on panic).
//!
//! Invariants covered:
//! * Theorem 1 (extrema-variance bound) over arbitrary data, including
//!   adversarial two-point and constant rows;
//! * zero false positives of every threshold algorithm on clean data;
//! * detect→localize→correct round-trip for random SEUs above threshold;
//! * quantization idempotence and monotonicity for every format;
//! * coordinator routing: responses match request ids 1:1 under load.

use vabft::fp::rounding::FloatSpec;
use vabft::prelude::*;
use vabft::threshold::{Threshold, ThresholdContext};

struct Cases {
    rng: Xoshiro256pp,
}

impl Cases {
    fn new(seed: u64) -> Cases {
        Cases { rng: Xoshiro256pp::seed_from_u64(seed) }
    }

    fn dims(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.uniform_u64((hi - lo + 1) as u64) as usize
    }

    fn dist(&mut self) -> Distribution {
        match self.rng.uniform_u64(5) {
            0 => Distribution::near_zero_normal(),
            1 => Distribution::normal_1_1(),
            2 => Distribution::uniform_pm1(),
            3 => Distribution::truncated_normal(),
            _ => Distribution::calibration(),
        }
    }

    fn model(&mut self) -> AccumModel {
        match self.rng.uniform_u64(5) {
            0 => AccumModel::cpu(Precision::F64),
            1 => AccumModel::cpu(Precision::F32),
            2 => AccumModel::gpu_highprec(Precision::F32),
            3 => AccumModel::wide(Precision::Bf16),
            _ => AccumModel::wide(Precision::F16),
        }
    }
}

#[test]
fn prop_extrema_variance_bound_holds() {
    let mut cases = Cases::new(0xE57);
    for case in 0..300 {
        let n = cases.dims(2, 400);
        let d = cases.dist();
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut cases.rng)).collect();
        let s = RowStats::of(&xs);
        assert!(
            s.variance <= s.extrema_var_bound() * (1.0 + 1e-12) + 1e-300,
            "case {case}: var {} > bound {} (n={n}, {})",
            s.variance,
            s.extrema_var_bound(),
            d.label()
        );
    }
}

#[test]
fn prop_no_false_positives_vabft() {
    let mut cases = Cases::new(0xFA15E);
    for case in 0..60 {
        let model = cases.model();
        let d = cases.dist();
        let (m, k, n) = (cases.dims(2, 24), cases.dims(4, 160), cases.dims(4, 96));
        let a = Matrix::sample_in(m, k, &d, model.input, &mut cases.rng);
        let b = Matrix::sample_in(k, n, &d, model.input, &mut cases.rng);
        for online in [false, true] {
            let ft = FtGemm::new(
                GemmEngine::new(model),
                Box::new(VabftThreshold::default()),
                VerifyPolicy::detect_only(online),
            );
            let out = ft.multiply(&a, &b).unwrap();
            assert_eq!(
                out.report.verdict,
                Verdict::Clean,
                "case {case}: FP with {model:?} {} online={online} ({}x{}x{})",
                d.label(),
                m,
                k,
                n
            );
        }
    }
}

#[test]
fn prop_seu_detect_localize_correct_roundtrip() {
    let mut cases = Cases::new(0x5E0);
    let mut corrected = 0;
    let mut total = 0;
    for case in 0..80 {
        let model = AccumModel::gpu_highprec(Precision::F32);
        let d = cases.dist();
        let (m, k, n) = (cases.dims(4, 16), cases.dims(8, 96), cases.dims(8, 64));
        let a = Matrix::sample_in(m, k, &d, model.input, &mut cases.rng);
        let b = Matrix::sample_in(k, n, &d, model.input, &mut cases.rng);
        let ft = FtGemm::new(
            GemmEngine::new(model),
            Box::new(VabftThreshold::default()),
            VerifyPolicy::default(),
        );
        let clean = ft.multiply(&a, &b).unwrap();
        // choose a fault magnitude safely above the row threshold
        let row = cases.rng.uniform_u64(m as u64) as usize;
        let col = cases.rng.uniform_u64(n as u64) as usize;
        let thr = clean
            .report
            .detections
            .first()
            .map(|d| d.threshold)
            .unwrap_or(1e-4);
        let mag = (thr * 1e4).max(0.5) * (1.0 + cases.rng.next_f64());
        let out = ft
            .multiply_with_injection(&a, &b, |o| {
                let v = o.acc.get(row, col);
                o.acc.set(row, col, v + mag);
                o.c.set(row, col, Precision::F32.quantize(v + mag));
            })
            .unwrap();
        total += 1;
        assert_ne!(out.report.verdict, Verdict::Clean, "case {case}: missed SEU");
        let diff = out.c.max_abs_diff(&clean.c);
        assert!(
            diff <= 1e-3 * (1.0 + clean.c.max_abs()),
            "case {case}: repair failed (diff {diff})"
        );
        if out.report.verdict == Verdict::Corrected {
            corrected += 1;
        }
    }
    assert!(corrected * 10 >= total * 8, "corrected only {corrected}/{total}");
}

#[test]
fn prop_quantization_idempotent_and_monotone() {
    let mut cases = Cases::new(0x0F0);
    let specs = [FloatSpec::BF16, FloatSpec::F16, FloatSpec::E4M3, FloatSpec::E5M2];
    for _ in 0..2000 {
        let bits = cases.rng.next_u64();
        let x = f64::from_bits(bits);
        if !x.is_finite() {
            continue;
        }
        for s in specs {
            let q = s.quantize(x);
            if q.is_nan() {
                continue;
            }
            assert_eq!(s.quantize(q), q, "not idempotent: {x} via {s:?}");
        }
    }
    // monotone on ordered pairs
    for _ in 0..2000 {
        let a = (cases.rng.next_f64() - 0.5) * 1e5;
        let b = (cases.rng.next_f64() - 0.5) * 1e5;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        for s in specs {
            let (ql, qh) = (s.quantize(lo), s.quantize(hi));
            if ql.is_nan() || qh.is_nan() {
                continue;
            }
            assert!(ql <= qh, "non-monotone {s:?}: q({lo})={ql} > q({hi})={qh}");
        }
    }
}

#[test]
fn prop_coordinator_routing_is_exact() {
    use std::sync::Arc;
    use vabft::coordinator::{Coordinator, CoordinatorConfig, GemmRequest};

    let cfg = CoordinatorConfig {
        workers: 3,
        queue_depth: 4,
        model: AccumModel::cpu(Precision::F32),
        policy: VerifyPolicy::default(),
        threshold: Arc::new(|| Box::new(VabftThreshold::default())),
        ..Default::default()
    };
    let c = Coordinator::start(cfg);
    let mut cases = Cases::new(0xC00D);
    let b = Matrix::sample(32, 16, &Distribution::normal_1_1(), &mut cases.rng);
    c.register_weight(0, &b);

    // every response's product must equal A_i · B for its own A_i
    let pairs: Vec<(Matrix, std::sync::mpsc::Receiver<_>)> = (0..24)
        .map(|i| {
            let a = Matrix::sample(3, 32, &Distribution::normal_1_1(), &mut cases.rng);
            let rx = c.submit(GemmRequest { a: a.clone(), weight: 0, inject: None });
            let _ = i;
            (a, rx)
        })
        .collect();
    for (a, rx) in pairs {
        let out = rx.recv().unwrap().result.unwrap();
        let want = GemmEngine::new(AccumModel::cpu(Precision::F32)).matmul(&a, &b).c;
        assert!(
            out.c.max_abs_diff(&want) < 1e-5,
            "response does not match its request"
        );
    }
    c.shutdown();
}
