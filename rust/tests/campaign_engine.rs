//! End-to-end campaign-engine regression: seeded determinism across
//! thread counts, the pinned CI smoke cell, and the quick-grid
//! detection-quality gates.
//!
//! The pinned expectations here are *theorems* of the engine, not
//! empirically frozen numbers: an exponent-MSB flip on the fused FP32
//! grid changes the struck value by ≥ 2 in magnitude (scale 2^±128, or
//! Inf/NaN), which exceeds any small-shape V-ABFT threshold by orders of
//! magnitude, so every such trial must be classified above-threshold and
//! detected. If one of these assertions ever fires, the detection
//! pipeline — not the test — regressed.

use vabft::bench_harness::{validate_schema, CAMPAIGN_SCHEMA};
use vabft::campaign::{self, plan, BitClass, BurstPattern, GridConfig, VerifyPoint};
use vabft::prelude::*;

const SMOKE_SEED: u64 = 0xD5EED;

#[test]
fn quick_grid_plans_at_least_200_cells() {
    let cells = plan(&GridConfig::quick(1));
    assert!(cells.len() >= 200, "quick grid too small: {}", cells.len());
    for p in [Precision::Bf16, Precision::F16, Precision::F32, Precision::F64] {
        assert!(cells.iter().any(|c| c.precision == p), "missing precision {p}");
    }
    for site in SiteClass::ALL {
        assert!(cells.iter().any(|c| c.site == site), "missing site {site:?}");
    }
    assert!(cells.iter().any(|c| c.verify == VerifyPoint::Offline));
}

/// Same seed ⇒ byte-identical `BENCH_campaign.json` at thread counts
/// 1/2/4 — the campaign's reproducibility contract (the JSON contains no
/// timing and no worker count; every trial's arithmetic is
/// schedule-preserving).
#[test]
fn campaign_json_is_byte_identical_across_thread_counts() {
    let cfg = GridConfig::smoke(SMOKE_SEED);
    let reference = campaign::to_doc(&campaign::run(&cfg, 1)).to_json();
    assert!(validate_schema(&reference, CAMPAIGN_SCHEMA).is_ok());
    for workers in [2usize, 4] {
        let json = campaign::to_doc(&campaign::run(&cfg, workers)).to_json();
        assert_eq!(reference, json, "campaign JSON diverged at {workers} workers");
    }
}

/// The `--shards` axis of the same contract: one campaign, same seed,
/// byte-identical JSON at shard counts 1/2/4 (workers fixed at 2 per
/// shard). Shard routing and cross-shard scheduling never touch a
/// trial's arithmetic or the planning-order collection — the gate CI
/// re-runs through the release CLI (`campaign --smoke --shards 2`).
#[test]
fn campaign_json_is_byte_identical_across_shard_counts() {
    let cfg = GridConfig::smoke(SMOKE_SEED);
    let reference = campaign::to_doc(&campaign::run_sharded(&cfg, 2, 1)).to_json();
    assert!(validate_schema(&reference, CAMPAIGN_SCHEMA).is_ok());
    for shards in [2usize, 4] {
        let json = campaign::to_doc(&campaign::run_sharded(&cfg, 2, shards)).to_json();
        assert_eq!(reference, json, "campaign JSON diverged at {shards} shards");
    }
}

/// The push-gated CI smoke cell: BF16 × FMA × fused × output-site ×
/// exponent-MSB, with pinned expected detections (see module docs for
/// why the counts are provable).
#[test]
fn smoke_cell_pins_expected_detections() {
    let cfg = GridConfig::smoke(SMOKE_SEED);
    let outcome = campaign::run(&cfg, 2);
    assert!(outcome.gates_hold(), "smoke gates failed");

    let cell = outcome
        .cells
        .iter()
        .find(|c| {
            c.spec.precision == Precision::Bf16
                && c.spec.site == SiteClass::Output
                && c.spec.bit_class == BitClass::ExpMsb
                && c.spec.verify == VerifyPoint::Fused
        })
        .expect("smoke grid lost its pinned cell");
    assert_eq!(cell.bit, 30, "fused BF16 flips address the FP32 work grid");
    assert_eq!(cell.trials, 4);
    assert_eq!(cell.above, 4, "every exp-MSB flip must classify above-threshold");
    assert_eq!(cell.detected, 4, "pinned expected detections");
    assert_eq!(cell.detected_above, 4);
    assert_eq!(cell.false_positives, 0);
    // Zero FP per row implies the worst clean noise sat under the
    // loosest issued threshold.
    assert!(cell.clean_noise <= cell.threshold_max, "noise above the threshold ceiling");

    // Checksum-site trials are reported as their own class — present in
    // the grid and never silently folded into data-fault misses.
    let checksum_cells: Vec<_> =
        outcome.cells.iter().filter(|c| c.spec.site == SiteClass::Checksum).collect();
    assert!(!checksum_cells.is_empty());
    for c in &checksum_cells {
        assert_eq!(
            c.detected_above, c.above,
            "checksum-site recall gate failed for cell {}",
            c.spec.index
        );
    }
}

/// The multi-fault axis of the smoke grid — the cells `campaign --smoke`
/// gates on in CI. Row bursts (simultaneous flips in one output row)
/// defeat row-direction localization: the D2/D1 ratio lands between
/// column weights, so the single-checksum baseline must recompute. The
/// grid encoding sees one fault per column in the same trial and
/// corrects in place, which is exactly the coverage the
/// `grid_exceeds_baseline` gate quantifies. The detection gates (recall
/// 1.0 over above-margin trials, zero false positives on the axis'
/// clean sweeps) must hold for *every* encoding — A-side checksums add
/// correction power, never detection drift.
#[test]
fn smoke_multi_fault_axis_grid_corrects_where_baseline_recomputes() {
    let cfg = GridConfig::smoke(SMOKE_SEED);
    let planned = campaign::plan_multi_fault(&cfg);
    assert!(!planned.is_empty(), "smoke grid lost its multi-fault cells");
    assert!(planned.iter().any(|c| c.pattern == BurstPattern::RowBurst));
    assert!(planned.iter().any(|c| c.encoding == EncodingMode::RowOnly));
    assert!(planned.iter().any(|c| c.encoding == EncodingMode::Grid));

    let outcome = campaign::run(&cfg, 2);
    assert_eq!(outcome.multi_cells.len(), planned.len());
    assert!(
        outcome.multi_fault_gates_hold(),
        "multi-fault detection gates failed: {} false positives over {} clean rows",
        outcome.multi_false_positives,
        outcome.multi_clean_rows
    );
    assert!(
        outcome.grid_exceeds_baseline(),
        "grid corrected-without-recompute ({}) must strictly exceed the row-only \
         baseline ({}) over {} trials",
        outcome.multi_corrected_no_recompute(EncodingMode::Grid),
        outcome.multi_corrected_no_recompute(EncodingMode::RowOnly),
        outcome.total_multi_trials()
    );
    // Strict excess implies the grid actually corrected something.
    assert!(outcome.multi_corrected_no_recompute(EncodingMode::Grid) > 0);
}

/// The protection-plan axis of the smoke grid — the cells that license
/// the per-layer planner to choose schemes on measured cost alone.
/// Every member of the planner's vocabulary (full, fused, grid, block-K,
/// replicate) must detect every injected exponent-MSB upset through the
/// *production* planned-dispatch path (a `PlanEntry` riding the weight
/// handle) with zero clean-sweep false positives, and the replication
/// scheme must recover an output bitwise-equal to the fault-free
/// reference — its recovery is recomputation from clean inputs, so
/// anything less is a bug, not noise.
#[test]
fn smoke_plan_axis_validates_every_scheme() {
    use vabft::planner::ProtectionScheme;
    let cfg = GridConfig::smoke(SMOKE_SEED);
    let planned = campaign::plan_protection(&cfg);
    assert!(!planned.is_empty(), "smoke grid lost its plan cells");
    // The axis covers the full vocabulary per precision.
    for scheme in ["full", "fused", "grid", "replicate"] {
        assert!(
            planned.iter().any(|c| c.scheme.label() == scheme),
            "plan axis missing scheme {scheme}"
        );
    }
    assert!(
        planned.iter().any(|c| matches!(c.scheme, ProtectionScheme::BlockK(_))),
        "plan axis missing the block-K scheme"
    );

    let outcome = campaign::run(&cfg, 2);
    assert_eq!(outcome.plan_cells.len(), planned.len());
    assert!(
        outcome.plan_gates_hold(),
        "plan gates failed: {} detected of {} trials, {} false positives over {} clean rows",
        outcome.total_plan_detected(),
        outcome.total_plan_trials(),
        outcome.plan_false_positives,
        outcome.plan_clean_rows
    );
    for c in &outcome.plan_cells {
        assert_eq!(
            c.detected, c.trials,
            "scheme {} missed an injected fault",
            c.spec.scheme.label()
        );
        assert_eq!(c.false_positives, 0, "scheme {} flagged clean rows", c.spec.scheme.label());
    }
    assert!(
        outcome.replication_bitwise_equal(),
        "replication recovery must be bitwise-equal to the fault-free reference"
    );
    // The gate is not vacuous: replication cells recovered real trials.
    let rep_trials: usize = outcome
        .plan_cells
        .iter()
        .filter(|c| c.spec.scheme == ProtectionScheme::Replicate)
        .map(|c| c.repaired_bitwise)
        .sum();
    assert!(rep_trials > 0, "no replication trials recovered");
}

/// The full quick grid upholds the paper's headline claims: recall 1.0
/// over the above-threshold population and zero false positives across
/// BF16/FP16/FP32/FP64 — the same gate `vabft campaign --quick` enforces
/// in CI.
#[test]
fn quick_grid_gates_hold() {
    let outcome = campaign::run(&GridConfig::quick(0xCA4A), 4);
    assert!(outcome.cells.len() >= 200);
    assert_eq!(
        outcome.total_false_positives(),
        0,
        "false positives over {} clean rows",
        outcome.total_clean_rows()
    );
    assert_eq!(
        outcome.total_detected_above(),
        outcome.total_above(),
        "recall {} over {} above-threshold trials",
        outcome.recall_above(),
        outcome.total_above()
    );
    // The grid must actually exercise the gate, with room to spare.
    assert!(
        outcome.total_above() >= 100,
        "campaign too weak: only {} above-threshold faults",
        outcome.total_above()
    );
    // Every *fused* exp-MSB output cell is fully detected — the theorem
    // class: a fused-grid exponent-MSB flip changes the struck value by
    // ≥ 2 (or to Inf/NaN), orders of magnitude above any fused
    // threshold at these shapes. (Offline cells verify against coarse
    // quantized-output thresholds, where sub-margin flips may
    // legitimately sail under — those are gated by the margin rule
    // only.)
    for c in outcome.cells.iter().filter(|c| {
        c.spec.site == SiteClass::Output
            && c.spec.bit_class == BitClass::ExpMsb
            && c.spec.verify == VerifyPoint::Fused
    }) {
        assert_eq!(c.detected, c.trials, "exp-MSB misses in cell {}", c.spec.index);
    }
    // The quick grid carries the multi-fault axis too, under the same
    // gates the nightly campaign enforces.
    assert!(!outcome.multi_cells.is_empty(), "quick grid lost its multi-fault axis");
    assert!(outcome.multi_fault_gates_hold(), "quick multi-fault detection gates failed");
    assert!(
        outcome.grid_exceeds_baseline(),
        "quick grid coverage gate: grid {} vs baseline {}",
        outcome.multi_corrected_no_recompute(EncodingMode::Grid),
        outcome.multi_corrected_no_recompute(EncodingMode::RowOnly)
    );
    // And the protection-plan axis, under the same gates the planner
    // smoke step enforces.
    assert!(!outcome.plan_cells.is_empty(), "quick grid lost its plan axis");
    assert!(outcome.plan_gates_hold(), "quick plan gates failed");
    assert!(outcome.replication_bitwise_equal(), "quick replication recovery gate failed");
}
