//! Coordinator weight-cache semantics: re-registration must fully
//! invalidate cached checksums/statistics (never serve a verification
//! decision computed from the old B), LRU eviction must only affect
//! id-based lookups, and the warm path must stay bitwise-faithful to a
//! freshly-started coordinator.

use std::sync::Arc;

use vabft::coordinator::{
    Coordinator, CoordinatorConfig, GemmRequest, InjectSpec, PreparedGemmRequest,
};
use vabft::prelude::*;

const K: usize = 96;
const N: usize = 48;

fn weights(seed: u64) -> Matrix {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::sample_in(K, N, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

fn act(seed: u64) -> Matrix {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::sample_in(8, K, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

fn start(capacity: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 1,
        weight_capacity: capacity,
        ..Default::default()
    })
}

/// Re-registering a weight id with a different matrix must evict the stale
/// checksum encoding and statistics. If any stale state survived, a clean
/// request against the new B would be verified against the old B's
/// checksums — a massive D1 on every row — so `Verdict::Clean` plus
/// bitwise equality with a fresh coordinator proves full invalidation.
#[test]
fn reregistration_fully_invalidates_stale_state() {
    let (b1, b2) = (weights(1), weights(2));
    let a = act(3);

    let c = start(16);
    c.register_weights(7, &b1);
    let out1 = c.call(GemmRequest { a: a.clone(), weight: 7, inject: None }).result.unwrap();
    assert_eq!(out1.report.verdict, Verdict::Clean);

    c.register_weights(7, &b2);
    let out2 = c.call(GemmRequest { a: a.clone(), weight: 7, inject: None }).result.unwrap();
    assert_eq!(
        out2.report.verdict,
        Verdict::Clean,
        "stale checksums/stats served after re-registration"
    );
    assert!(
        out1.c.max_abs_diff(&out2.c) > 0.0,
        "distinct weights must give distinct products"
    );

    // Ground truth: a coordinator that has only ever seen b2.
    let fresh = start(16);
    fresh.register_weights(7, &b2);
    let want = fresh.call(GemmRequest { a, weight: 7, inject: None }).result.unwrap();
    assert_eq!(
        out2.c.data(),
        want.c.data(),
        "post-re-registration output must be bitwise-identical to a fresh registration"
    );
    fresh.shutdown();
    c.shutdown();
}

/// After re-registration, detection still works against the *new* weights:
/// an injected upset is caught and the repaired output matches the new
/// clean product — decisions are computed from the new B's state.
#[test]
fn detection_after_reregistration_uses_new_weights() {
    let (b1, b2) = (weights(4), weights(5));
    let a = act(6);

    let c = start(16);
    c.register_weights(1, &b1);
    let _ = c.call(GemmRequest { a: a.clone(), weight: 1, inject: None });
    c.register_weights(1, &b2);

    let clean = c.call(GemmRequest { a: a.clone(), weight: 1, inject: None }).result.unwrap();
    let faulty = c
        .call(GemmRequest {
            a,
            weight: 1,
            inject: Some(InjectSpec::output(2, 5, 25)),
        })
        .result
        .unwrap();
    assert_ne!(faulty.report.verdict, Verdict::Clean, "fault missed after re-registration");
    // Repair recovers the new-B product to within ~one BF16 output ulp at
    // this magnitude (values ≈ 96 → ulp 0.5); an un-invalidated stale
    // checksum would leave an O(|value|) corruption instead.
    assert!(
        faulty.c.max_abs_diff(&clean.c) < 1.0,
        "repair should recover the new-B product: diff {}",
        faulty.c.max_abs_diff(&clean.c)
    );
    c.shutdown();
}

/// LRU eviction: the least-recently-used id drops out at capacity; its id
/// lookups error, while resident ids and explicit handles keep working.
#[test]
fn lru_eviction_errors_by_id_but_handles_survive() {
    let c = start(2);
    let h1 = c.register_weights(1, &weights(10));
    let h2 = c.register_weights(2, &weights(11));

    // Touch 1: now 2 is least-recently-used.
    assert!(c.call(GemmRequest { a: act(20), weight: 1, inject: None }).result.is_ok());
    c.register_weights(3, &weights(12));

    assert!(c.weight_resident(1));
    assert!(!c.weight_resident(2), "LRU entry must be evicted at capacity");
    assert!(c.weight_resident(3));

    let err = c.call(GemmRequest { a: act(21), weight: 2, inject: None });
    assert!(err.result.is_err(), "evicted id must error, not silently serve stale weights");

    // The evicted weight's handle still works (no cache lookup)…
    let via_handle = c.call_prepared(PreparedGemmRequest {
        a: act(21),
        weights: Arc::clone(&h2),
        inject: None,
    });
    assert_eq!(via_handle.result.unwrap().report.verdict, Verdict::Clean);
    // …and so does a resident id's handle.
    let via_h1 = c.call_prepared(PreparedGemmRequest { a: act(22), weights: h1, inject: None });
    assert!(via_h1.result.is_ok());
    c.shutdown();
}

/// Blockwise-prepared coordinator: weights registered at block_k
/// granularity still verify clean and catch injected faults, and the
/// cache invalidation semantics are identical.
#[test]
fn blockwise_prepared_coordinator_serves_and_invalidates() {
    let c = Coordinator::start(CoordinatorConfig {
        workers: 1,
        block_k: Some(32), // K = 96 → 3 blocks per weight
        ..Default::default()
    });
    let (b1, b2) = (weights(30), weights(31));
    let a = act(32);

    c.register_weights(5, &b1);
    let out = c.call(GemmRequest { a: a.clone(), weight: 5, inject: None }).result.unwrap();
    assert_eq!(out.report.verdict, Verdict::Clean);
    assert_eq!(out.report.rows_checked, 8 * 3, "per-block verification: M rows × 3 blocks");

    c.register_weights(5, &b2);
    let out2 = c.call(GemmRequest { a: a.clone(), weight: 5, inject: None }).result.unwrap();
    assert_eq!(out2.report.verdict, Verdict::Clean, "stale blockwise state after re-register");

    let faulty = c
        .call(GemmRequest {
            a,
            weight: 5,
            inject: Some(InjectSpec::output(1, 3, 26)),
        })
        .result
        .unwrap();
    assert_ne!(faulty.report.verdict, Verdict::Clean);
    c.shutdown();
}
