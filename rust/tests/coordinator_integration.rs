//! Coordinator-level integration: the serving pipeline under load, with
//! mixed clean/faulty traffic, weight swaps and backpressure.

use std::sync::Arc;

use vabft::coordinator::{Coordinator, CoordinatorConfig, GemmRequest, InjectSpec};
use vabft::prelude::*;

fn setup(workers: usize, online: bool) -> Coordinator {
    let cfg = CoordinatorConfig {
        workers,
        queue_depth: 8,
        model: AccumModel::wide(Precision::Bf16),
        policy: if online {
            VerifyPolicy::default()
        } else {
            VerifyPolicy::offline()
        },
        threshold: Arc::new(|| Box::new(VabftThreshold::default())),
        ..Default::default()
    };
    Coordinator::start(cfg)
}

fn weights(seed: u64, k: usize, n: usize) -> Matrix {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::sample_in(k, n, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

fn act(seed: u64, m: usize, k: usize) -> Matrix {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::sample_in(m, k, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
}

#[test]
fn mixed_traffic_all_faults_caught_no_false_alarms() {
    let c = setup(2, true);
    c.register_weight(1, &weights(1, 96, 48));
    let mut faulty = 0;
    let receivers: Vec<_> = (0..40)
        .map(|i| {
            let inject = if i % 5 == 0 {
                faulty += 1;
                Some(InjectSpec::output(
                    (i % 8) as usize,
                    (i % 48) as usize,
                    25, // f32 exponent bit (online grid)
                ))
            } else {
                None
            };
            (
                inject.is_some(),
                c.submit(GemmRequest { a: act(100 + i, 8, 96), weight: 1, inject }),
            )
        })
        .collect();
    let mut detected = 0;
    for (was_faulty, r) in receivers {
        let resp = r.recv().unwrap();
        let out = resp.result.expect("ok");
        if was_faulty {
            assert_ne!(out.report.verdict, Verdict::Clean, "fault missed");
            detected += 1;
        } else {
            assert_eq!(out.report.verdict, Verdict::Clean, "false alarm");
        }
    }
    assert_eq!(detected, faulty);
    assert_eq!(c.metrics().jobs_completed.get(), 40);
    assert!(c.metrics().faults_detected.get() >= faulty as u64);
    c.shutdown();
}

#[test]
fn repaired_outputs_match_clean_outputs() {
    let c = setup(1, true);
    c.register_weight(9, &weights(2, 64, 32));
    let a = act(3, 8, 64);
    let clean = c
        .call(GemmRequest { a: a.clone(), weight: 9, inject: None })
        .result
        .unwrap()
        .c;
    for bit in [24u32, 26, 28] {
        let out = c
            .call(GemmRequest {
                a: a.clone(),
                weight: 9,
                inject: Some(InjectSpec::output(4, 7, bit)),
            })
            .result
            .unwrap();
        assert_ne!(out.report.verdict, Verdict::Clean, "bit {bit} missed");
        let diff = out.c.max_abs_diff(&clean);
        assert!(diff < 1e-2, "bit {bit}: repair diff {diff}");
    }
    c.shutdown();
}

#[test]
fn throughput_counters_and_latency_histogram_populate() {
    let c = setup(2, true);
    c.register_weight(1, &weights(4, 64, 32));
    let rxs: Vec<_> = (0..16)
        .map(|i| c.submit(GemmRequest { a: act(50 + i, 4, 64), weight: 1, inject: None }))
        .collect();
    for r in rxs {
        r.recv().unwrap().result.unwrap();
    }
    let m = c.metrics();
    assert_eq!(m.jobs_submitted.get(), 16);
    assert_eq!(m.jobs_completed.get(), 16);
    assert!(m.latency.count() == 16);
    assert!(m.latency.mean() > std::time::Duration::ZERO);
    assert!(!m.summary().is_empty());
    c.shutdown();
}

#[test]
fn shutdown_drains_outstanding_work() {
    let c = setup(1, false);
    c.register_weight(1, &weights(5, 128, 64));
    let rxs: Vec<_> = (0..8)
        .map(|i| c.submit(GemmRequest { a: act(60 + i, 16, 128), weight: 1, inject: None }))
        .collect();
    c.shutdown(); // must not deadlock; queued jobs complete
    let mut done = 0;
    for r in rxs {
        if let Ok(resp) = r.recv() {
            resp.result.unwrap();
            done += 1;
        }
    }
    assert_eq!(done, 8);
}
