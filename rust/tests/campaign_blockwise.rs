//! Fault-campaign regression over the unified blockwise pipeline.
//!
//! A seeded campaign of single-event upsets (exponent and high-mantissa
//! bit flips on the FP32 accumulator) through `FtGemm` at
//! `VerifyGranularity::BlockK` — i.e. the shared FT pipeline at
//! `block_k = KC` — asserting, for BF16-wide and FP32 accumulation
//! models:
//!
//! * detection recall = 1.0 for every fault whose magnitude clears the
//!   row's V-ABFT threshold with margin (detection is then a theorem, not
//!   a statistic: |D1| ≥ |δ| − noise and noise ≤ T by the zero-FP bound);
//! * zero false positives across all clean runs;
//! * correct K-block localization (every detection lands in the injected
//!   block) and column localization for corrected rows;
//! * the repaired product matches the clean product.
//!
//! Sizes are small (8×128×16, 4 K-blocks) so the whole campaign stays
//! well under 10 s in CI.

use vabft::abft::{FtGemm, Verdict, VerifyGranularity, VerifyPolicy};
use vabft::gemm::GemmEngine;
use vabft::prelude::*;
use vabft::threshold::{Threshold, ThresholdContext};

const M: usize = 8;
const K: usize = 128;
const N: usize = 16;
const BLOCK_K: usize = 32;

fn operands(seed: u64, input: Precision) -> (Matrix, Matrix) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let d = Distribution::normal_1_1();
    (
        Matrix::sample_in(M, K, &d, input, &mut rng),
        Matrix::sample_in(K, N, &d, input, &mut rng),
    )
}

/// V-ABFT threshold of `row` for the injected block — computed exactly as
/// the pipeline computes it (per-block operands, online context).
fn block_threshold(a: &Matrix, b: &Matrix, model: AccumModel, block: usize, row: usize) -> f64 {
    let k0 = block * BLOCK_K;
    let a_blk = Matrix::from_fn(M, BLOCK_K, |i, j| a.get(i, k0 + j));
    let b_blk = Matrix::from_fn(BLOCK_K, N, |i, j| b.get(k0 + i, j));
    VabftThreshold::default().thresholds(&a_blk, &b_blk, &ThresholdContext::online(model))[row]
}

fn run_campaign(model: AccumModel, seed_base: u64) {
    // Exponent bits (24–27) and high-mantissa bits (20–22) of the FP32
    // accumulator grid — the verify grid of the online policy.
    let bits: [u32; 7] = [20, 21, 22, 24, 25, 26, 27];
    let bw = FtGemm::new(
        GemmEngine::new(model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(BLOCK_K)),
    );

    let mut rng = Xoshiro256pp::seed_from_u64(seed_base ^ 0xCA3);
    let mut above_threshold = 0usize;
    let mut detected_above = 0usize;

    for trial in 0..6u64 {
        let (a, b) = operands(seed_base + trial, model.input);

        // Clean run: zero false positives, and the reference product.
        let clean = bw.multiply(&a, &b).unwrap();
        assert_eq!(
            clean.report.verdict,
            Verdict::Clean,
            "trial {trial}: false positive on clean run ({model:?})"
        );
        assert!(clean.report.detections.is_empty());

        for &bit in &bits {
            let block = rng.uniform_u64((K / BLOCK_K) as u64) as usize;
            let row = rng.uniform_u64(M as u64) as usize;
            let col = rng.uniform_u64(N as u64) as usize;
            let flip = BitFlip::new(bit, Precision::F32);

            let mut delta = 0.0f64;
            let out = bw
                .multiply_with_block_injection(&a, &b, |bi, o| {
                    if bi == block {
                        let old = o.acc.get(row, col);
                        let (new, _) = flip.apply(old);
                        delta = new - old;
                        o.acc.set(row, col, new);
                    }
                })
                .unwrap();

            let thr = block_threshold(&a, &b, model, block, row);
            let above = delta.abs() > 4.0 * thr || !delta.is_finite();
            if !above {
                // Sub-threshold faults are allowed to go unnoticed; only
                // bound the damage: no wrong-block attribution.
                assert!(out.detection_blocks.iter().all(|&bl| bl == block));
                continue;
            }
            above_threshold += 1;
            assert_ne!(
                out.report.verdict,
                Verdict::Clean,
                "trial {trial} bit {bit}: missed fault |δ|={:.3e} > 4T={:.3e} \
                 (block {block}, row {row}, col {col}, {model:?})",
                delta.abs(),
                4.0 * thr
            );
            detected_above += 1;

            // K-block localization: every detection must attribute to the
            // injected block, and the flagged row must be the injected one.
            assert!(
                !out.detection_blocks.is_empty()
                    && out.detection_blocks.iter().all(|&bl| bl == block),
                "trial {trial} bit {bit}: wrong block attribution {:?} (expected {block})",
                out.detection_blocks
            );
            assert!(
                out.report.detections.iter().any(|d| d.row == row),
                "trial {trial} bit {bit}: flagged rows {:?} missing injected row {row}",
                out.report.detections.iter().map(|d| d.row).collect::<Vec<_>>()
            );
            // Column localization whenever the syndrome was corrected.
            for d in out.report.detections.iter().filter(|d| d.corrected) {
                assert_eq!(d.col, Some(col), "trial {trial} bit {bit}: wrong column");
            }

            // Repair restores the clean product (correction or recompute).
            let dmax = out.c.max_abs_diff(&clean.c);
            assert!(
                dmax <= 1e-2 * (1.0 + clean.c.max_abs()),
                "trial {trial} bit {bit}: repair failed, diff {dmax}"
            );
        }
    }

    // Recall over the above-threshold population must be exactly 1.
    assert_eq!(
        detected_above, above_threshold,
        "recall < 1.0 for {model:?}: {detected_above}/{above_threshold}"
    );
    // And the campaign must actually have exercised detections.
    assert!(
        above_threshold >= 10,
        "campaign too weak: only {above_threshold} above-threshold faults ({model:?})"
    );
}

#[test]
fn blockwise_campaign_bf16_wide() {
    run_campaign(AccumModel::wide(Precision::Bf16), 0xB16);
}

#[test]
fn blockwise_campaign_fp32() {
    run_campaign(AccumModel::gpu_highprec(Precision::F32), 0xF32);
}
